//! TransferSan mutation corpus + property P15.
//!
//! Each mutation test takes a *clean* graph — usually one the real
//! pipeline compiled — applies one targeted corruption, and asserts the
//! analyzer flags it under the expected lint name. The corruptions mirror
//! real wiring mistakes the passes could make: a dropped completion dep,
//! a duplicated transfer, a stranded release. Mutations edit the public
//! `Graph::ops` fields directly (the P9 idiom); `inputs` are never edited
//! in place because the consumer index is maintained by the mutation
//! methods.
//!
//! P15 (bottom): the analyzer raises **zero deny-level findings** on
//! anything the suite's pipelines produce — default compilation, the
//! recompute decision pass, the SLO throttle's spill/split rewrites — and
//! its static peak bound dominates the simulated peak of arbitrary valid
//! linearizations of those graphs.

use hyperoffload::analysis::{analyze, lints, AnalysisReport, LintConfig, LintLevel};
use hyperoffload::graph::{Graph, GraphBuilder, OpId, OpKind, Reach, Tier, TrackedSet};
use hyperoffload::passes::{Compiler, ExecOrderPass, OffloadPolicy, Severity, SloThrottle};
use hyperoffload::sim::{simulate, HwConfig};
use hyperoffload::util::rng::Rng;

fn hw() -> HwConfig {
    HwConfig::test_default()
}

fn run(g: &Graph) -> AnalysisReport {
    let order = g.topo_order().unwrap();
    let anc = Reach::ancestors(g, &order, TrackedSet::CacheOps);
    analyze(g, &order, &anc, &hw())
}

fn names(r: &AnalysisReport) -> Vec<&'static str> {
    r.findings.iter().map(|f| f.lint).collect()
}

fn denies(r: &AnalysisReport) -> Vec<&'static str> {
    let cfg = LintConfig::default();
    r.findings
        .iter()
        .map(|f| f.lint)
        .filter(|l| cfg.level_of(l) == LintLevel::Deny)
        .collect()
}

/// The Fig. 4 forward/backward chain, compiled by the default pipeline —
/// the canonical graph with inserted Store/Prefetch round trips.
fn compiled_fig4() -> Graph {
    let mut g = GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9);
    let report = Compiler::new(hw()).verify(true).compile(&mut g).unwrap();
    assert!(!report.inserted.is_empty(), "fixture must offload something");
    g
}

/// First inserted round trip of `g`: `(tensor, store, prefetch)` with the
/// prefetch wired after the store.
fn first_round_trip(g: &Graph) -> (usize, OpId, OpId) {
    for op in &g.ops {
        if let OpKind::Store { tensor, .. } = op.kind {
            if let Some(pf) = g.ops.iter().find(|o| {
                matches!(o.kind, OpKind::Prefetch { tensor: pt, .. } if pt == tensor)
                    && o.control_deps.contains(&op.id)
            }) {
                return (tensor, op.id, pf.id);
            }
        }
    }
    panic!("no store/prefetch round trip in the compiled graph");
}

/// A reader of `t` ordered after `pf` by an explicit control dep.
fn guarded_reader(g: &Graph, t: usize, pf: OpId) -> OpId {
    g.consumers_of(t)
        .iter()
        .copied()
        .find(|&c| !g.op(c).kind.is_cache_op() && g.op(c).control_deps.contains(&pf))
        .expect("round trip has no dep-guarded reader")
}

// ---------------------------------------------------------------------
// Deny-level corruptions
// ---------------------------------------------------------------------

#[test]
fn race_store_consumer_when_reader_loses_its_prefetch_dep() {
    let mut g = compiled_fig4();
    assert!(denies(&run(&g)).is_empty(), "fixture not clean");
    let (t, _, pf) = first_round_trip(&g);
    let c = guarded_reader(&g, t, pf);
    g.ops[c].control_deps.retain(|&d| d != pf);
    let r = run(&g);
    assert!(
        names(&r).contains(&lints::RACE_STORE_CONSUMER),
        "dropped completion dep not flagged: {:?}",
        r.findings
    );
}

#[test]
fn race_store_consumer_when_prefetch_loses_its_store_dep() {
    // Unordered (store, reload): the store can land mid-reload — and the
    // reload itself may run while the first copy is still resident, so
    // the acquire/acquire warning fires alongside.
    let mut g = compiled_fig4();
    let (_, st, pf) = first_round_trip(&g);
    g.ops[pf].control_deps.retain(|&d| d != st);
    let r = run(&g);
    assert!(names(&r).contains(&lints::RACE_STORE_CONSUMER), "got {:?}", r.findings);
    assert!(names(&r).contains(&lints::RACE_ACQUIRE_ACQUIRE), "got {:?}", r.findings);
}

#[test]
fn residency_double_release_on_duplicated_store() {
    let mut g = compiled_fig4();
    let (t, _, _) = first_round_trip(&g);
    g.add_op(
        format!("store.dup.{}", g.tensor(t).name),
        OpKind::store(t),
        vec![t],
        vec![],
    );
    let r = run(&g);
    assert!(names(&r).contains(&lints::RESIDENCY_DOUBLE_RELEASE), "got {:?}", r.findings);
}

#[test]
fn residency_release_nonresident_on_retargeted_store() {
    // A store whose kind points at a tensor that never reaches the
    // device: the release frees bytes that were never allocated.
    let mut g = compiled_fig4();
    let (_, st, _) = first_round_trip(&g);
    let rogue = g.add_tensor("rogue.remote", 1 << 20, Tier::Remote);
    g.ops[st].kind = OpKind::store(rogue);
    let r = run(&g);
    assert!(
        names(&r).contains(&lints::RESIDENCY_RELEASE_NONRESIDENT),
        "got {:?}",
        r.findings
    );
}

#[test]
fn residency_use_after_release_on_late_reader() {
    // A reader wired after the store with no reload between: forced
    // use-after-free, not merely a race.
    let mut g = compiled_fig4();
    let (t, st, _) = first_round_trip(&g);
    let rogue = g.add_op(
        "rogue.read",
        OpKind::Compute { flops: 1e9, bytes_accessed: 0 },
        vec![t],
        vec![],
    );
    g.add_control_dep(rogue, st);
    let r = run(&g);
    let hit = r
        .findings
        .iter()
        .find(|f| f.lint == lints::RESIDENCY_USE_AFTER_RELEASE)
        .unwrap_or_else(|| panic!("use-after-release not flagged: {:?}", r.findings));
    assert_eq!(hit.op, Some(rogue));
}

#[test]
fn residency_no_acquire_when_consumer_skips_the_load() {
    // Weight-streaming chain: a consumer of a remote weight loses its dep
    // on the inserted prefetch and can dispatch before the bytes land.
    let mut g = GraphBuilder::chain_with_remote_weights(16, 4e12, 1 << 20, 200 << 20).0;
    let report = Compiler::new(hw()).verify(true).compile(&mut g).unwrap();
    assert!(!report.inserted.is_empty());
    assert!(denies(&run(&g)).is_empty(), "fixture not clean");
    let (t, pf) = g
        .ops
        .iter()
        .find_map(|o| match o.kind {
            OpKind::Prefetch { tensor, .. } if g.tensor(tensor).home == Tier::Remote => {
                Some((tensor, o.id))
            }
            _ => None,
        })
        .expect("no remote-weight prefetch inserted");
    let c = guarded_reader(&g, t, pf);
    g.ops[c].control_deps.retain(|&d| d != pf);
    let r = run(&g);
    assert!(names(&r).contains(&lints::RESIDENCY_NO_ACQUIRE), "got {:?}", r.findings);
}

#[test]
fn race_store_consumer_on_stranded_detach() {
    // The recompute rewrite's shape: a Detach freeing the original copy
    // after its last keeper. Strand the Detach and the free races the
    // reader.
    let mut g = Graph::new();
    let w = g.add_tensor("act", 8 << 20, Tier::Device);
    g.add_op("p", OpKind::Compute { flops: 1e9, bytes_accessed: 0 }, vec![], vec![w]);
    let c = g.add_op("use", OpKind::Compute { flops: 1e9, bytes_accessed: 0 }, vec![w], vec![]);
    let dt = g.add_op("detach.act", OpKind::Detach { tensor: w }, vec![w], vec![]);
    g.add_control_dep(dt, c);
    assert!(denies(&run(&g)).is_empty(), "fixture not clean");
    g.ops[dt].control_deps.clear();
    let r = run(&g);
    assert!(names(&r).contains(&lints::RACE_STORE_CONSUMER), "got {:?}", r.findings);
}

#[test]
fn chunk_sibling_release_when_parent_reader_overtakes() {
    // The split-round-trip shape: a chunk view of the parent's storage
    // leaves and returns while the parent-wide reader waits on the chunk
    // prefetch. Drop that dep and the chunk store can beat the reader.
    let mut g = Graph::new();
    let w = g.add_tensor("act", 8 << 20, Tier::Device);
    let _p = g.add_op("p", OpKind::Compute { flops: 1e9, bytes_accessed: 0 }, vec![], vec![w]);
    let c1 = g.add_op("c1", OpKind::Compute { flops: 1e9, bytes_accessed: 0 }, vec![w], vec![]);
    let ck = g.add_chunk_tensor(w, "act.chunk0", 4 << 20);
    let stc = g.add_op("store.act.chunk0", OpKind::store(ck), vec![ck], vec![]);
    g.add_control_dep(stc, c1);
    let pfc = g.add_op("prefetch.act.chunk0", OpKind::prefetch(ck), vec![ck], vec![]);
    g.add_control_dep(pfc, stc);
    // The split rewrite lists the chunk as a data input of every window
    // consumer (refcount bookkeeping) and orders it after the reload.
    let c2 = g.add_op(
        "c2",
        OpKind::Compute { flops: 1e9, bytes_accessed: 0 },
        vec![w, ck],
        vec![],
    );
    g.add_control_dep(c2, pfc);
    assert!(denies(&run(&g)).is_empty(), "fixture not clean");
    g.ops[c2].control_deps.retain(|&d| d != pfc);
    let r = run(&g);
    assert!(names(&r).contains(&lints::CHUNK_SIBLING_RELEASE), "got {:?}", r.findings);
}

// ---------------------------------------------------------------------
// Warn-level corruptions: flagged, but not deny-level
// ---------------------------------------------------------------------

#[test]
fn race_acquire_acquire_on_duplicated_prefetch() {
    let mut g = compiled_fig4();
    let (t, _, _) = first_round_trip(&g);
    g.add_op(
        format!("prefetch.dup.{}", g.tensor(t).name),
        OpKind::prefetch(t),
        vec![t],
        vec![],
    );
    let r = run(&g);
    assert!(names(&r).contains(&lints::RACE_ACQUIRE_ACQUIRE), "got {:?}", r.findings);
    // A wasted transfer, not a soundness hole: no deny lint may fire.
    assert!(denies(&r).is_empty(), "warn-level corruption denied: {:?}", r.findings);
}

#[test]
fn ledger_leak_on_consumerless_prefetch() {
    let mut g = compiled_fig4();
    let orphan = g.add_tensor("orphan.remote", 1 << 20, Tier::Remote);
    g.add_op("prefetch.orphan", OpKind::prefetch(orphan), vec![orphan], vec![]);
    let r = run(&g);
    assert!(names(&r).contains(&lints::LEDGER_LEAK), "got {:?}", r.findings);
    assert!(denies(&r).is_empty(), "warn-level corruption denied: {:?}", r.findings);
}

#[test]
fn peak_unbounded_on_starved_device() {
    let g = compiled_fig4();
    let order = g.topo_order().unwrap();
    let anc = Reach::ancestors(&g, &order, TrackedSet::CacheOps);
    let mut starved = hw();
    starved.device_capacity = 1 << 20; // 1 MiB device vs 8 MiB activations
    let r = analyze(&g, &order, &anc, &starved);
    assert!(names(&r).contains(&lints::PEAK_UNBOUNDED), "got {:?}", r.findings);
    // Allow by default (the pinned order may still fit) — promotable.
    let mut cfg = LintConfig::default();
    assert!(hyperoffload::analysis::to_diagnostics(&r, &cfg)
        .iter()
        .all(|d| d.severity != Severity::Error));
    cfg.set(lints::PEAK_UNBOUNDED, LintLevel::Deny);
    assert!(hyperoffload::analysis::to_diagnostics(&r, &cfg)
        .iter()
        .any(|d| d.severity == Severity::Error));
}

// ---------------------------------------------------------------------
// P15: no false positives on anything the suite's pipelines emit, and
// the static bound dominates the simulated peak of sampled orders.
// ---------------------------------------------------------------------

/// Same adversarial generator as the proptest suite: layered DAG with
/// random remote weights, skips and fan-out.
fn random_graph(rng: &mut Rng) -> Graph {
    let n = rng.usize(4, 40);
    let mut b = GraphBuilder::new();
    let mut tensors: Vec<usize> = Vec::new();
    for i in 0..n {
        let bytes = 1u64 << rng.usize(16, 27);
        let out = b.tensor(&format!("t{i}"), bytes, Tier::Device);
        let mut inputs = Vec::new();
        for _ in 0..rng.usize(0, 4.min(tensors.len() + 1)) {
            if !tensors.is_empty() {
                inputs.push(*rng.choose(&tensors));
            }
        }
        if rng.next_f64() < 0.3 {
            let w = b.tensor(&format!("w{i}"), 1u64 << rng.usize(20, 28), Tier::Remote);
            inputs.push(w);
        }
        inputs.sort_unstable();
        inputs.dedup();
        b.compute(&format!("op{i}"), rng.f64_range(1e9, 1e13), 0, inputs, vec![out]);
        tensors.push(out);
    }
    b.build()
}

fn assert_deny_clean_and_bound_dominates(g: &Graph, what: &str) {
    let r = run(g);
    assert!(
        denies(&r).is_empty(),
        "{what}: analyzer denied legitimate pipeline output: {:?}",
        r.findings
    );
    for seed in 0..4u64 {
        let order = g.topo_order_seeded(seed).unwrap();
        let sim = simulate(g, &order, &hw());
        assert!(
            sim.peak_device_bytes <= r.peak_bound_bytes,
            "{what} seed {seed}: simulated peak {} > static bound {}",
            sim.peak_device_bytes,
            r.peak_bound_bytes
        );
    }
}

#[test]
fn p15_default_pipeline_output_is_deny_clean() {
    // Compiling *with* the sanitizer stage must succeed (no Error-level
    // diagnostics), and direct analysis of the result must agree.
    let mut g = GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9);
    let report = Compiler::new(hw()).verify(true).sanitize(true).compile(&mut g).unwrap();
    assert!(report.diagnostics.iter().all(|d| d.severity != Severity::Error));
    assert!(
        report.diagnostics.iter().any(|d| d.pass == lints::PASS),
        "sanitizer left no audit trail in the report"
    );
    assert_deny_clean_and_bound_dominates(&g, "fig4");

    let mut g = GraphBuilder::chain_with_remote_weights(16, 4e12, 1 << 20, 200 << 20).0;
    Compiler::new(hw()).verify(true).sanitize(true).compile(&mut g).unwrap();
    assert_deny_clean_and_bound_dominates(&g, "weight-stream");
}

#[test]
fn p15_random_dags_compile_deny_clean_across_pipelines() {
    for seed in 0..12u64 {
        let mut rng = Rng::new(seed + 21_000);
        let g0 = random_graph(&mut rng);
        let policy = OffloadPolicy { min_bytes: 1 << 18, ..Default::default() };

        let mut a = g0.clone();
        Compiler::new(hw())
            .policy(policy.clone())
            .verify(true)
            .sanitize(true)
            .compile(&mut a)
            .unwrap_or_else(|e| panic!("seed {seed}: default pipeline {e}"));
        assert_deny_clean_and_bound_dominates(&a, &format!("random {seed}"));

        // The recompute decision pass replaces round trips with Detach +
        // replay clones — its output must satisfy the analyzer too.
        let mut b = g0.clone();
        Compiler::new(hw())
            .policy(policy)
            .recompute_vs_offload()
            .verify(true)
            .sanitize(true)
            .compile(&mut b)
            .unwrap_or_else(|e| panic!("seed {seed}: recompute pipeline {e}"));
        assert_deny_clean_and_bound_dominates(&b, &format!("recompute {seed}"));
    }
}

#[test]
fn p15_slo_throttle_rewrites_stay_deny_clean() {
    // (a) Spill: a deferrable writeback shrunk to a `.keep` chunk view.
    let mut g = Graph::new();
    let w = g.add_tensor("kv.wb", 32 << 20, Tier::Device);
    g.set_deferrable(w, true);
    let st = g.add_op("store.kv.wb", OpKind::store(w), vec![w], vec![]);
    let out = g.add_tensor("out", 0, Tier::Device);
    let c = g.add_op("decode", OpKind::Compute { flops: 40e6, bytes_accessed: 0 }, vec![], vec![out]);
    let h = g.add_op("host", OpKind::HostWork { us: 5.0 }, vec![], vec![]);
    g.add_control_dep(h, c);
    g.add_control_dep(h, st);
    let report = Compiler::empty(hw())
        .pass(ExecOrderPass)
        .pass(SloThrottle::default())
        .slo_us(50.0)
        .verify(true)
        .sanitize(true)
        .compile(&mut g)
        .unwrap();
    assert!(report.deferred_bytes > 0, "spill must fire for the rewrite to be exercised");
    assert_deny_clean_and_bound_dominates(&g, "spill");

    // (b) Split: a monolithic activation round trip chunked into partial
    // round trips (chunk views of the parent's storage).
    let mut b = GraphBuilder::new();
    let act = b.tensor("act", 256 << 20, Tier::Device);
    let sink = b.tensor("sink", 0, Tier::Device);
    b.compute("fwd", 1e6, 0, vec![], vec![act]);
    let mut prev = None;
    for i in 0..10 {
        let t = b.tensor(&format!("m{i}"), 0, Tier::Device);
        let inputs = prev.map(|p| vec![p]).unwrap_or_default();
        // ~80 ms of compute per mid op at the 1 TFLOP/s test device: the
        // 256 MiB round trip (~540 ms of wire) hides with headroom, so
        // the insertion pass reliably commits it.
        let o = b.compute(&format!("mid{i}"), 8e10, 0, inputs, vec![t]);
        if i == 0 {
            b.dep(o, 0);
        }
        prev = Some(t);
    }
    b.compute("bwd", 1e6, 0, vec![act, prev.unwrap()], vec![sink]);
    let g0 = b.build();

    let mut base = g0.clone();
    let rb = Compiler::new(hw()).compile(&mut base).unwrap();
    assert!(!rb.inserted.is_empty(), "fixture must produce a round trip");
    let slo = simulate(&base, &rb.order, &hw()).makespan_us * 1.1;

    let mut split = g0;
    let throttle =
        SloThrottle { split_min_bytes: 64 << 20, defer_prefetches: false, ..Default::default() };
    Compiler::new(hw())
        .slo_us(slo)
        .pass(throttle)
        .verify(true)
        .sanitize(true)
        .compile(&mut split)
        .unwrap();
    assert_deny_clean_and_bound_dominates(&split, "split");
}
