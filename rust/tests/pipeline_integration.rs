//! Integration across the compiler pipeline + simulator + baselines:
//! whole-system behaviours no single module test covers.

use hyperoffload::graph::{GraphBuilder, OpId, Tier};
use hyperoffload::passes::Compiler;
use hyperoffload::runtime_sched::{simulate_reactive, ReactiveConfig, ReactiveMode};
use hyperoffload::serving::{EngineConfig, ModelCost, SimServingEngine, WorkloadConfig};
use hyperoffload::sim::{simulate, HwConfig, GB};
use hyperoffload::training::{
    baseline_step, hierarchical_step, hierarchical_step_with, ModelPreset, ParallelCfg,
    StepOptions,
};
use hyperoffload::util::rng::Rng;

fn hw() -> HwConfig {
    HwConfig::ascend910c_like()
}

/// Random layered DAG with remote weights and offloadable activations.
fn random_workload(seed: u64, n_ops: usize) -> hyperoffload::graph::Graph {
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new();
    let mut prev: Option<usize> = None;
    for i in 0..n_ops {
        let act_bytes = 1 << rng.usize(20, 27);
        let act = b.tensor(&format!("a{i}"), act_bytes as u64, Tier::Device);
        let mut inputs = Vec::new();
        if let Some(p) = prev {
            inputs.push(p);
        }
        if rng.next_f64() < 0.4 {
            let w = b.tensor(&format!("w{i}"), (1 << rng.usize(22, 28)) as u64, Tier::Remote);
            inputs.push(w);
        }
        let flops = rng.f64_range(1e11, 5e12);
        b.compute(&format!("op{i}"), flops, act_bytes as u64, inputs, vec![act]);
        prev = Some(act);
    }
    b.build()
}

#[test]
fn compiled_schedule_never_slower_than_program_order_across_seeds() {
    for seed in 0..12u64 {
        let g0 = random_workload(seed, 24);
        let base_order = g0.topo_order().unwrap();
        // Legalise remote loads for the baseline comparison the same way
        // the reactive runtime would (on-demand), then compare ours.
        let reactive = simulate_reactive(&g0, &ReactiveConfig::default(), &hw());

        let mut g = g0.clone();
        let report = Compiler::new(hw()).verify(true).compile(&mut g).unwrap();
        assert!(g.is_valid_order(&report.order), "seed {seed}");
        let ours = simulate(&g, &report.order, &hw());

        assert!(
            ours.makespan_us <= reactive.makespan_us * 1.001,
            "seed {seed}: compiled {} > reactive {}",
            ours.makespan_us,
            reactive.makespan_us
        );
        let _ = base_order;
    }
}

#[test]
fn fig3_motivation_ordering_holds() {
    // serial > runtime-prefetch > graph-driven, on a weight-streaming
    // workload (the Fig. 3 trichotomy).
    // 12.5 ms ops vs 6.4 ms weight transfers: the graph-driven schedule
    // can hide every transfer; the runtime keeps its control bubbles.
    let g0 = GraphBuilder::chain_with_remote_weights(16, 4e12, 1 << 20, 2 * GB / 10).0;
    let serial = simulate_reactive(&g0, &ReactiveConfig::default(), &hw());
    let runtime_pf = simulate_reactive(
        &g0,
        &ReactiveConfig { mode: ReactiveMode::Prefetch { lookahead: 2 }, compaction_every: 4, compaction_us: 2000.0 },
        &hw(),
    );
    let mut g = g0.clone();
    let report = Compiler::new(hw()).compile(&mut g).unwrap();
    let ours = simulate(&g, &report.order, &hw());

    assert!(serial.makespan_us > runtime_pf.makespan_us);
    assert!(runtime_pf.makespan_us > ours.makespan_us);
}

#[test]
fn training_bandwidth_sweep_is_monotone() {
    // Fig. 6 mechanism at integration level: hierarchical step time is
    // non-increasing in pool bandwidth.
    let m = ModelPreset::llama8b();
    let p = ParallelCfg::llama_hier();
    let mut last = f64::INFINITY;
    for bw in [20.0, 33.6, 40.0, 50.0, 60.0, 70.0] {
        let s = hierarchical_step(&m, &p, &hw().with_pool_bandwidth(bw));
        // Small (<3%) wobbles are legitimate: the candidate selector's DMA
        // budget admits more offloads as bandwidth grows, and the marginal
        // candidate may not hide perfectly at its admission point.
        assert!(
            s.total_ms <= last * 1.03,
            "step time rose at {bw} GB/s: {} > {last}",
            s.total_ms
        );
        last = s.total_ms.min(last);
    }
}

#[test]
fn training_baseline_insensitive_to_pool_bandwidth() {
    let m = ModelPreset::llama8b();
    let p = ParallelCfg::llama_no2();
    let a = baseline_step(&m, &p, &hw().with_pool_bandwidth(20.0));
    let b = baseline_step(&m, &p, &hw().with_pool_bandwidth(70.0));
    assert!((a.total_ms - b.total_ms).abs() < 1e-9);
}

#[test]
fn serving_end_to_end_baseline_vs_hierarchical_tables_shape() {
    // Table 3+4+5 shapes in one integration run.
    let model = ModelCost::dsv3_nsa_like();
    let hw64 = hw().with_device_capacity(64 * GB);

    // Long sequences near capacity: 2 x 35k tokens x 228 KiB ~= 16 GB,
    // just inside the baseline's ~19 GB device KV budget.
    let long = WorkloadConfig::long_sequence(2, 35_000, 512, 9).generate();
    let base = SimServingEngine::new(EngineConfig::baseline(hw64.clone(), model.clone()))
        .run(long.clone())
        .unwrap();
    let hier = SimServingEngine::new(EngineConfig::hierarchical(hw64.clone(), model.clone()))
        .run(long)
        .unwrap();

    // Peak memory drops by roughly the KV size (Table 3's ~26%).
    assert!(hier.peak_device_bytes < base.peak_device_bytes);
    // Defrag: present in baseline under churn... at minimum never present
    // in hierarchical (Table 4).
    assert_eq!(hier.defrag_events, 0);
    // Throughput of hierarchical within a sane band of baseline.
    assert!(hier.throughput_tok_per_s > base.throughput_tok_per_s * 0.5);
}

#[test]
fn cache_op_count_scales_with_offloadable_tensors() {
    let mut counts = Vec::new();
    for n in [8usize, 16, 32] {
        let mut g = GraphBuilder::chain_with_remote_weights(n, 2e12, 1 << 20, GB / 10).0;
        let report = Compiler::new(hw()).compile(&mut g).unwrap();
        counts.push(report.inserted.len());
    }
    assert!(counts[0] < counts[1] && counts[1] < counts[2], "{counts:?}");
}

#[test]
fn exec_order_determinism_across_runs() {
    let mk = || {
        let mut g = random_workload(99, 20);
        let report = Compiler::new(hw()).compile(&mut g).unwrap();
        report.order
    };
    let a: Vec<OpId> = mk();
    let b: Vec<OpId> = mk();
    assert_eq!(a, b, "compilation must be deterministic");
}

/// Golden: the `Compiler` session with default passes is bit-identical to
/// the deprecated `compile()` shim on the §5.1 miniature and this suite's
/// workloads — the contract that lets every caller migrate safely.
#[test]
#[allow(deprecated)]
fn golden_compiler_matches_deprecated_compile() {
    use hyperoffload::passes::{compile, ExecOrderConfig, OffloadPolicy};

    let mut workloads: Vec<hyperoffload::graph::Graph> =
        vec![GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9)];
    for seed in 0..6u64 {
        workloads.push(random_workload(seed, 24));
    }
    workloads.push(GraphBuilder::chain_with_remote_weights(16, 4e12, 1 << 20, 2 * GB / 10).0);

    for (i, g0) in workloads.into_iter().enumerate() {
        let mut old_g = g0.clone();
        let old =
            compile(&mut old_g, &hw(), &OffloadPolicy::default(), &ExecOrderConfig::default());
        let mut new_g = g0;
        let new = Compiler::new(hw()).compile(&mut new_g).unwrap();

        assert_eq!(old.order, new.order, "workload {i}: order diverged");
        assert_eq!(old.inserted, new.inserted, "workload {i}: insertions diverged");
        assert_eq!(old.rejected, new.rejected, "workload {i}: rejections diverged");
        assert_eq!(old.moved, new.moved, "workload {i}: refinement diverged");

        let so = simulate(&old_g, &old.order, &hw());
        let sn = simulate(&new_g, &new.order, &hw());
        assert_eq!(so.peak_device_bytes, sn.peak_device_bytes, "workload {i}: peak diverged");
        assert_eq!(
            so.makespan_us.to_bits(),
            sn.makespan_us.to_bits(),
            "workload {i}: makespan diverged"
        );
        assert_eq!(so.dma_bytes, sn.dma_bytes, "workload {i}: traffic diverged");
    }
}

/// Acceptance criterion of the decision-pass PR: on a link-saturated
/// Table-1 recompute-on configuration, the `RecomputeVsOffload` pipeline
/// yields strictly lower simulated step time than offload-only, at equal
/// or lower peak device bytes. The device is squeezed to 48 GB so the
/// capacity-aware elision keeps the activation round trip (ample HBM
/// would make "just stay resident" the winner), and the pool link runs at
/// 2 GB/s: the accepted activation round trip costs ~1 s of wire time on
/// the bottleneck DMA streams against a ~13 ms forward replay.
#[test]
fn table1_recompute_on_beats_pure_offload_on_saturated_link() {
    let m = ModelPreset::llama8b();
    let par = ParallelCfg { recompute: true, ..ParallelCfg::llama_hier() };
    let shw = hw().with_pool_bandwidth(2.0).with_device_capacity(48 * GB);

    let offload_only = hierarchical_step_with(
        &m,
        &par,
        &shw,
        &StepOptions { recompute: false, ..StepOptions::for_par(&par) },
    );
    let with_recompute = hierarchical_step(&m, &par, &shw); // for_par: recompute on

    assert!(
        with_recompute.recompute_ms > 0.0,
        "decision pass never fired on the saturated link"
    );
    assert!(
        with_recompute.total_ms < offload_only.total_ms,
        "recompute-on not faster: {} !< {}",
        with_recompute.total_ms,
        offload_only.total_ms
    );
    assert!(
        with_recompute.peak_bytes <= offload_only.peak_bytes,
        "recompute-on raised peak: {} > {}",
        with_recompute.peak_bytes,
        offload_only.peak_bytes
    );
}

/// The training preset wires `ElideRedundantTransfers` behind the
/// capacity-aware policy: with ample HBM the round trips collapse to
/// plain residency (less fabric traffic, no slower); under a squeezed
/// device they must survive.
#[test]
fn training_preset_elides_only_with_headroom() {
    let m = ModelPreset::llama8b();
    let par = ParallelCfg::llama_hier();

    let ample = hierarchical_step(&m, &par, &hw());
    let no_elide = hierarchical_step_with(
        &m,
        &par,
        &hw(),
        &StepOptions { elide: false, ..StepOptions::for_par(&par) },
    );
    // Elision never slows the step and never raises the realised peak
    // beyond the device.
    assert!(ample.total_ms <= no_elide.total_ms * 1.01);
    assert!(ample.peak_bytes < hw().device_capacity as f64);

    // Squeezed device: headroom test fails, round trips survive, and the
    // realised peak stays *below* the ample-memory peak (the bytes really
    // do leave the device).
    let squeezed = hierarchical_step(&m, &par, &hw().with_device_capacity(48 * GB));
    assert!(
        squeezed.peak_bytes < ample.peak_bytes,
        "squeezed run must keep offloading: {} !< {}",
        squeezed.peak_bytes,
        ample.peak_bytes
    );
}

/// `ElideRedundantTransfers` cuts fabric traffic on the offload
/// round-trip workload without costing makespan (acceptance criterion of
/// the session-API redesign).
#[test]
fn elide_redundant_transfers_cuts_fabric_traffic() {
    let thw = HwConfig::test_default(); // 1 GiB device vs 32 MB of acts
    let g0 = GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9);

    let mut g1 = g0.clone();
    let r1 = Compiler::new(thw.clone()).compile(&mut g1).unwrap();
    let s1 = simulate(&g1, &r1.order, &thw);
    assert!(!r1.inserted.is_empty(), "fixture must offload something");

    let mut g2 = g0;
    let r2 = Compiler::new(thw.clone())
        .elide_redundant_transfers()
        .verify(true)
        .compile(&mut g2)
        .unwrap();
    let s2 = simulate(&g2, &r2.order, &thw);

    assert!(r2.elided > 0, "nothing elided");
    assert!(
        s2.dma_bytes < s1.dma_bytes,
        "fabric traffic not reduced: {} vs {}",
        s2.dma_bytes,
        s1.dma_bytes
    );
    assert!(
        s2.makespan_us <= s1.makespan_us * 1.01,
        "elision cost makespan: {} vs {}",
        s2.makespan_us,
        s1.makespan_us
    );
}
