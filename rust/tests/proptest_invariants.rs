//! Property tests over coordinator invariants (seeded-sweep style; the
//! proptest crate is absent from the offline mirror, so properties are
//! checked over many seeded random instances — same invariants, explicit
//! generators).
//!
//! Invariants:
//!  P1 Algorithm 1 output is always a valid topological order.
//!  P2 The simulator's memory accounting never goes negative and peak
//!     bounds every residency sample.
//!  P3 Offload insertion preserves graph acyclicity for any plan.
//!  P4 The device allocator never exceeds capacity, and compaction
//!     preserves the set of live allocations.
//!  P5 The KV manager's device footprint stays within its budget under
//!     FullOffload for arbitrary admit/decode/retire interleavings.
//!  P6 The router never loses requests and balances within bound.
//!  P7 Cluster conservation: for any workload and replica count,
//!     dispatched == completed + rejected (no request lost), the shared
//!     pool never exceeds its capacity, and every replica's residency
//!     curve has non-decreasing timestamps.
//!  P8 The Compiler session's final order is a valid topological order
//!     satisfying every cache-op control dep, and the between-stage
//!     verifier stays clean on arbitrary DAGs.
//!  P9 The verifier rejects hand-corrupted IR: a Prefetch of a dangling
//!     tensor, and a consumer not ordered after transfer completion.
//!  P10 Cyclic graphs surface as structured errors (try_build /
//!     CompileError::Cycle) naming the culprit ops, instead of a panic.
//!  P11 Decision passes never regress the schedule they are given: on
//!     random DAGs, adding `RecomputeVsOffload` never increases the
//!     simulated makespan (and never raises peak bytes), and adding
//!     `SloThrottle` never raises peak device bytes above the no-throttle
//!     schedule while keeping makespan within the SLO budget.
//!  P12 The compiled serving path conserves bytes and partial residency
//!     is sound: on random serving workloads the compiled step-graph path
//!     and the retired analytic oracle agree on total KV bytes moved, and
//!     chunked Store/Prefetch round trips never raise peak residency
//!     above the unsplit schedule (while moving the same bytes within the
//!     same budget).
//!  P13 Incremental analyses are exact: after arbitrary journalled
//!     mutation sequences the `AnalysisCache`'s delta-patched topological
//!     order and lifetime table are bit-identical to a fresh
//!     recomputation, and `SimTrace::resume` at any cut (with or without
//!     speculative extra deps) reproduces the full simulation bit for
//!     bit — schedule times, peak bytes, makespan.
//!  P14 Prefix sharing conserves refcounts exactly: under random
//!     admit/decode/fork/preempt/retire sequences across managers sharing
//!     one pool and one prefix index, the pool ledger always equals the
//!     deduped sum (private bytes + resident shared bytes, each shared
//!     block counted once), draining empties it exactly, and a prefix-hit
//!     admission is byte-identical downstream to a cold prefill of the
//!     same tokens.
//!  P16 The tiered ledger conserves bytes per tier: under random
//!     reserve/release/demote/promote/shared-acquire/shared-move
//!     interleavings over a 3- or 5-tier stack, every tier's ledger
//!     always equals its modelled private + shared holdings, failed
//!     moves change nothing, and draining empties the whole stack.
//!  P17 A mirrored two-tier TierTopology is the identity: on random
//!     DAGs, compiling with `tiers = two_tier(hw)` and the TierPlacement
//!     pass enabled produces a bit-identical schedule (order, op kinds,
//!     simulated makespan/peak/bytes) to the legacy no-topology compile.
//!  P18 The lease ledger conserves harvested bytes: under random
//!     borrow/release/revoke/demote interleavings across several lenders
//!     and a capacity-limited pool, every lender's lent bytes match the
//!     reference model, `total_lent + revoked_bytes` always equals
//!     `borrowed − released` (no byte minted or dropped), every revoked
//!     byte lands in the pool exactly once (`pool.used == revoked_bytes`),
//!     failed demotions change nothing, and no lease ever exceeds its
//!     lender's registered spare capacity.

use hyperoffload::graph::{Graph, GraphBuilder, OpKind, Tier};
use hyperoffload::kvcache::{KvCacheManager, KvPolicy, NsaConfig, PrefixIndex};
use hyperoffload::memory::{DeviceAllocator, LeaseLedger, PoolHandle, SharedAcquire, TieredLedger};
use hyperoffload::passes::{
    refine, AnalysisCache, CompileError, Compiler, ExecOrderConfig, LifetimeAnalysis,
    OffloadPolicy, SloThrottle,
};
use hyperoffload::serving::{
    template_prefix_hashes, ClusterConfig, EngineConfig, ModelCost, Request, RoutePolicy,
    Router, SimCluster, SimServingEngine, WorkloadConfig,
};
use hyperoffload::sim::{simulate, HwConfig, SimTrace, TierTopology, GB};
use hyperoffload::util::rng::Rng;

const CASES: u64 = 60;

fn hw(rng: &mut Rng) -> HwConfig {
    HwConfig {
        compute_tflops: rng.f64_range(10.0, 400.0),
        hbm_gbps: rng.f64_range(400.0, 3000.0),
        d2r_gbps: rng.f64_range(5.0, 100.0),
        r2d_gbps: rng.f64_range(5.0, 100.0),
        link_latency_us: rng.f64_range(0.0, 50.0),
        net_gbps: rng.f64_range(10.0, 100.0),
        host_overhead_us: rng.f64_range(0.0, 500.0),
        device_capacity: 1 << 36,
        remote_capacity: 1 << 42,
        tiers: None,
        peer: None,
    }
}

/// Random DAG: layered, with random remote weights, skip connections and
/// fan-out — adversarial for ordering code.
fn random_graph(rng: &mut Rng) -> Graph {
    let n = rng.usize(4, 40);
    let mut b = GraphBuilder::new();
    let mut tensors: Vec<usize> = Vec::new();
    for i in 0..n {
        let bytes = 1u64 << rng.usize(16, 27);
        let out = b.tensor(&format!("t{i}"), bytes, Tier::Device);
        let mut inputs = Vec::new();
        // up to 3 random earlier tensors
        for _ in 0..rng.usize(0, 4.min(tensors.len() + 1)) {
            if !tensors.is_empty() {
                inputs.push(*rng.choose(&tensors));
            }
        }
        if rng.next_f64() < 0.3 {
            let w = b.tensor(&format!("w{i}"), 1u64 << rng.usize(20, 28), Tier::Remote);
            inputs.push(w);
        }
        inputs.sort_unstable();
        inputs.dedup();
        b.compute(&format!("op{i}"), rng.f64_range(1e9, 1e13), 0, inputs, vec![out]);
        tensors.push(out);
    }
    b.build()
}

#[test]
fn p1_refinement_always_valid_topological_order() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let hw = hw(&mut rng);
        let mut g = random_graph(&mut rng);
        // Insert offload ops too, then refine.
        let order = g.topo_order().unwrap();
        let policy = OffloadPolicy { min_bytes: 1 << 18, ..Default::default() };
        hyperoffload::passes::prefetch_insert::run(&mut g, &order, &hw, &policy);
        let r = refine(&mut g, &hw, &ExecOrderConfig::default());
        assert!(g.is_valid_order(&r.order), "seed {seed}");
        assert!(g.validate().is_ok(), "seed {seed}");
    }
}

#[test]
fn p2_residency_never_negative_and_peak_bounds() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 1000);
        let hw = hw(&mut rng);
        let mut g = random_graph(&mut rng);
        let report = Compiler::new(hw.clone()).compile(&mut g).unwrap();
        let sim = simulate(&g, &report.order, &hw);
        for &(t, bytes) in &sim.residency {
            assert!(t >= 0.0, "seed {seed}");
            assert!(bytes <= sim.peak_device_bytes, "seed {seed}: {bytes} > peak");
        }
        assert!(sim.exposed_comm_us >= 0.0 && sim.overlapped_comm_us >= 0.0);
        assert!(sim.makespan_us >= sim.compute_busy_us - 1e-6, "seed {seed}");
    }
}

#[test]
fn p3_insertion_preserves_acyclicity() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 2000);
        let hw = hw(&mut rng);
        let mut g = random_graph(&mut rng);
        let order = g.topo_order().unwrap();
        let policy = OffloadPolicy {
            min_bytes: 1 << rng.usize(16, 24),
            min_idle_gap: rng.usize(1, 5),
            coverage: rng.f64_range(0.1, 2.0),
            max_candidates: rng.usize(0, 10),
        };
        hyperoffload::passes::prefetch_insert::run(&mut g, &order, &hw, &policy);
        assert!(g.topo_order().is_ok(), "seed {seed}: cycle introduced");
    }
}

#[test]
fn p4_allocator_capacity_and_compaction_preservation() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 3000);
        let cap = 1u64 << rng.usize(16, 22);
        let mut a = DeviceAllocator::new(cap);
        let mut live: Vec<(u64, u64)> = Vec::new(); // (id, size)
        for _ in 0..300 {
            if rng.next_f64() < 0.6 || live.is_empty() {
                let size = 1 + rng.gen_range(0, cap / 8);
                if let Ok((id, _)) = a.alloc(size) {
                    live.push((id, size));
                }
            } else {
                let i = rng.usize(0, live.len());
                let (id, _) = live.swap_remove(i);
                a.free(id).unwrap();
            }
            assert!(a.used() <= a.capacity(), "seed {seed}");
            let expect: u64 = live.iter().map(|&(_, s)| s).sum();
            assert_eq!(a.used(), expect, "seed {seed}: live-set mismatch");
        }
        // Compaction keeps every allocation.
        let before = a.used();
        a.compact();
        assert_eq!(a.used(), before, "seed {seed}");
        assert_eq!(a.largest_free_extent(), a.free_total(), "seed {seed}");
    }
}

#[test]
fn p5_kv_device_footprint_bounded_under_offload() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 4000);
        let hw = HwConfig::ascend910c_like();
        let mut m = KvCacheManager::new(
            KvPolicy::FullOffload,
            NsaConfig { block_tokens: 1 << rng.usize(4, 8), ..Default::default() },
            1 << rng.usize(10, 18),
            1 << 30,
        );
        let budget = m.working_set_bytes;
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..200 {
            match rng.usize(0, 3) {
                0 => {
                    let toks = rng.usize(1, 5000);
                    if m.admit(next_id, toks, &hw).is_ok() {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
                1 if !live.is_empty() => {
                    let id = *rng.choose(&live);
                    m.decode_step(id, &hw).unwrap();
                }
                2 if !live.is_empty() => {
                    let i = rng.usize(0, live.len());
                    let id = live.swap_remove(i);
                    m.retire(id).unwrap();
                }
                _ => {}
            }
            assert!(
                m.device_kv_bytes() <= budget,
                "seed {seed}: working set exceeded ({} > {budget})",
                m.device_kv_bytes()
            );
        }
    }
}

#[test]
fn p7_cluster_conserves_requests_pool_and_time() {
    for seed in 0..24u64 {
        let mut rng = Rng::new(seed + 6000);
        let n_replicas = rng.usize(1, 5);
        let hier = rng.next_f64() < 0.5;
        let model = ModelCost {
            weights_bytes: 8 * GB,
            act_bytes: GB,
            prefill_flops_per_token: 16e9,
            decode_flops_per_token: 16e9,
            kv_bytes_per_token: 64 * 1024,
        };
        // Squeeze the shared pool sometimes so rejections/preemptions
        // actually exercise the conservation paths.
        let mut hw = HwConfig::ascend910c_like().with_device_capacity(
            10 * GB + rng.gen_range(0, 16) * GB,
        );
        hw.remote_capacity = GB + rng.gen_range(0, 8) * GB;
        let engine = if hier {
            EngineConfig::hierarchical(hw, model)
        } else {
            EngineConfig::baseline(hw, model)
        };
        let wl = WorkloadConfig {
            n_requests: rng.usize(4, 40),
            mean_interarrival_us: if rng.next_f64() < 0.5 { 0.0 } else { 20_000.0 },
            prompt_min: 64,
            prompt_max: rng.usize(512, 30_000),
            gen_min: 1,
            gen_max: rng.usize(8, 200),
            seed: seed * 7 + 1,
            prefix_share_ratio: 0.0,
            prefix_templates: 0,
            prefix_tokens: 0,
            prefix_block_tokens: 64,
            prefix_zipf_s: 0.0,
            burst_phases: 0,
            burst_factor: 1.0,
        }
        .generate();
        let n_requests = wl.len() as u64;
        let route = if rng.next_f64() < 0.5 {
            RoutePolicy::LeastLoaded
        } else {
            RoutePolicy::RoundRobin
        };
        let report = SimCluster::new(
            ClusterConfig::new(engine, n_replicas).with_route(route),
        )
        .run(wl)
        .unwrap();
        assert_eq!(report.dispatched, n_requests, "seed {seed}: dispatch lost");
        assert_eq!(
            report.dispatched,
            report.completed + report.rejected,
            "seed {seed}: request lost (preempted events: {})",
            report.preempted_events
        );
        assert!(
            report.pool_peak_bytes <= report.pool_capacity_bytes,
            "seed {seed}: pool over capacity"
        );
        for (i, r) in report.per_replica.iter().enumerate() {
            for w in r.residency.windows(2) {
                assert!(
                    w[1].0 >= w[0].0,
                    "seed {seed} replica {i}: residency time went backwards"
                );
            }
            assert!(r.residency.iter().all(|&(_, b)| b <= r.peak_device_bytes));
        }
    }
}

#[test]
fn p8_compiler_order_valid_and_verifier_clean_on_random_dags() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 7000);
        let hw = hw(&mut rng);
        let mut g = random_graph(&mut rng);
        let report = Compiler::new(hw)
            .policy(OffloadPolicy { min_bytes: 1 << 18, ..Default::default() })
            .verify(true)
            .compile(&mut g)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert!(g.is_valid_order(&report.order), "seed {seed}");
        let mut pos = vec![usize::MAX; g.ops.len()];
        for (i, &o) in report.order.iter().enumerate() {
            pos[o] = i;
        }
        for op in &g.ops {
            // Every control dep around cache operators is satisfied by the
            // final order (prefetch completion precedes consumers, stores
            // follow their anchor, etc.).
            for &d in &op.control_deps {
                if op.kind.is_cache_op() || g.op(d).kind.is_cache_op() {
                    assert!(
                        pos[d] < pos[op.id],
                        "seed {seed}: cache-op dep {d} !< {}",
                        op.id
                    );
                }
            }
        }
    }
}

#[test]
fn p9_verifier_rejects_corrupted_prefetch() {
    let hw = HwConfig::ascend910c_like();

    // (a) Prefetch pointing at a dangling tensor id.
    let mut b = GraphBuilder::new();
    let w = b.tensor("w", 1 << 20, Tier::Remote);
    let x = b.tensor("x", 64, Tier::Device);
    let pf = b.prefetch("pf.w", w);
    let c = b.compute("mm", 1e9, 0, vec![w], vec![x]);
    b.dep(c, pf);
    let mut g = b.build();
    g.ops[pf].kind = OpKind::prefetch(999);
    g.ops[pf].inputs = vec![999];
    match Compiler::empty(hw.clone()).verify(true).compile(&mut g) {
        Err(CompileError::Verify { violations, .. }) => {
            assert!(!violations.is_empty());
        }
        other => panic!("dangling prefetch accepted: {other:?}"),
    }

    // (b) Consumer with no dependency path from the prefetch: placement
    // after the transfer is not completion ordering (streams overlap).
    let mut b = GraphBuilder::new();
    let w = b.tensor("w", 1 << 20, Tier::Remote);
    let y = b.tensor("y", 64, Tier::Device);
    let _pf = b.prefetch("pf.w", w);
    let _c = b.compute("mm", 1e9, 0, vec![w], vec![y]); // no dep on pf
    let mut g = b.build();
    match Compiler::empty(hw).verify(true).compile(&mut g) {
        Err(CompileError::Verify { .. }) => {}
        other => panic!("consumer-before-completion accepted: {other:?}"),
    }
}

#[test]
fn p10_cycles_surface_as_structured_errors() {
    let build = || {
        let mut b = GraphBuilder::new();
        let t0 = b.tensor("t0", 8, Tier::Device);
        let t1 = b.tensor("t1", 8, Tier::Device);
        let a = b.compute("a", 1e6, 0, vec![], vec![t0]);
        let c = b.compute("c", 1e6, 0, vec![t0], vec![t1]);
        b.dep(a, c); // back edge
        (b, a, c)
    };
    let (b, a, c) = build();
    let err = b.try_build().unwrap_err();
    assert!(err.culprit_ops.contains(&a) && err.culprit_ops.contains(&c));

    let (b, a, c) = build();
    let mut g = b.build(); // deferred path still constructs the graph
    match Compiler::new(HwConfig::ascend910c_like()).compile(&mut g) {
        Err(CompileError::Cycle { culprit_ops }) => {
            assert!(culprit_ops.contains(&a) && culprit_ops.contains(&c));
        }
        other => panic!("expected CompileError::Cycle, got {other:?}"),
    }
}

#[test]
fn p11_decision_passes_never_regress_schedules() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed + 8000);
        let hw = hw(&mut rng);
        let g0 = random_graph(&mut rng);
        let policy = OffloadPolicy { min_bytes: 1 << 18, ..Default::default() };

        // Baseline: the default pipeline (lifetime → insert → exec-order).
        let mut a = g0.clone();
        let ra = Compiler::new(hw.clone())
            .policy(policy.clone())
            .compile(&mut a)
            .unwrap_or_else(|e| panic!("seed {seed}: baseline {e}"));
        let sa = simulate(&a, &ra.order, &hw);

        // + RecomputeVsOffload: speculate/validate must never regress.
        let mut b = g0.clone();
        let rb = Compiler::new(hw.clone())
            .policy(policy.clone())
            .recompute_vs_offload()
            .verify(true)
            .compile(&mut b)
            .unwrap_or_else(|e| panic!("seed {seed}: recompute {e}"));
        let sb = simulate(&b, &rb.order, &hw);
        assert!(
            sb.makespan_us <= sa.makespan_us * (1.0 + 1e-9),
            "seed {seed}: recompute increased makespan {} > {}",
            sb.makespan_us,
            sa.makespan_us
        );
        assert!(
            sb.peak_device_bytes <= sa.peak_device_bytes,
            "seed {seed}: recompute raised peak {} > {}",
            sb.peak_device_bytes,
            sa.peak_device_bytes
        );

        // + SloThrottle at 5% slack: peak must never rise above the
        // no-throttle schedule and the budget must hold.
        let slo = sa.makespan_us * 1.05;
        let mut c = g0.clone();
        let rc = Compiler::new(hw.clone())
            .policy(policy)
            .slo_us(slo)
            .slo_throttle()
            .verify(true)
            .compile(&mut c)
            .unwrap_or_else(|e| panic!("seed {seed}: throttle {e}"));
        let sc = simulate(&c, &rc.order, &hw);
        assert!(
            sc.peak_device_bytes <= sa.peak_device_bytes,
            "seed {seed}: throttle raised peak {} > {}",
            sc.peak_device_bytes,
            sa.peak_device_bytes
        );
        assert!(
            sc.makespan_us <= slo.max(sa.makespan_us) * (1.0 + 1e-9),
            "seed {seed}: throttle broke the budget: {} vs slo {slo}",
            sc.makespan_us
        );
    }
}

#[test]
fn p12_compiled_serving_conserves_bytes_and_chunking_bounds_peak() {
    // (a) On random serving workloads the compiled step-graph path and
    // the retired analytic oracle agree on total KV bytes moved — every
    // writeback byte the throttle defers still reaches the pool.
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed + 12_000);
        let model = ModelCost {
            weights_bytes: GB,
            act_bytes: GB / 2,
            prefill_flops_per_token: 16e9,
            decode_flops_per_token: rng.f64_range(1e9, 32e9),
            kv_bytes_per_token: 64 * 1024,
        };
        let hw = HwConfig::ascend910c_like().with_device_capacity(16 * GB);
        let n = rng.usize(1, 6);
        let wl: Vec<Request> = (0..n)
            .map(|i| Request {
                id: i as u64,
                arrival_us: 0.0,
                prompt_tokens: rng.usize(64, 4096),
                gen_tokens: rng.usize(1, 80),
                block_hashes: vec![],
            })
            .collect();
        let slo = if rng.next_f64() < 0.5 {
            Some(rng.f64_range(1.0, 10_000.0))
        } else {
            None
        };
        let mk = |oracle: bool| EngineConfig {
            decode_slo_us: slo,
            analytic_oracle: oracle,
            ..EngineConfig::hierarchical(hw.clone(), model.clone())
        };
        let compiled = SimServingEngine::new(mk(false)).run(wl.clone()).unwrap();
        let oracle = SimServingEngine::new(mk(true)).run(wl.clone()).unwrap();
        assert_eq!(
            compiled.kv_transfer_bytes, oracle.kv_transfer_bytes,
            "seed {seed}: compiled path lost bytes (slo {slo:?})"
        );
        assert_eq!(compiled.tokens_generated, oracle.tokens_generated, "seed {seed}");
        assert_eq!(compiled.rejected_requests, oracle.rejected_requests, "seed {seed}");
    }

    // (b) Chunked Store/Prefetch round trips (partial-tensor residency)
    // never raise peak residency above the unsplit schedule, conserve
    // fabric bytes, and respect the budget. Deferral is disabled in both
    // arms so the comparison isolates the chunking rewrite.
    for seed in 0..15u64 {
        let mut rng = Rng::new(seed + 13_000);
        let hw = HwConfig::test_default();
        let act_bytes = (128u64 << 20) + (rng.gen_range(0, 8) << 25);
        let n_mid = rng.usize(8, 14);
        let mid_flops = rng.f64_range(1.0e11, 2.0e11);
        let build = || {
            let mut b = GraphBuilder::new();
            let act = b.tensor("act", act_bytes, Tier::Device);
            let sink = b.tensor("sink", 0, Tier::Device);
            b.compute("fwd", 1e6, 0, vec![], vec![act]);
            let mut prev = None;
            for i in 0..n_mid {
                let t = b.tensor(&format!("m{i}"), 0, Tier::Device);
                let inputs = prev.map(|p| vec![p]).unwrap_or_default();
                let o = b.compute(&format!("mid{i}"), mid_flops, 0, inputs, vec![t]);
                if i == 0 {
                    b.dep(o, 0);
                }
                prev = Some(t);
            }
            b.compute("bwd", 1e6, 0, vec![act, prev.unwrap()], vec![sink]);
            b.build()
        };

        let mut base = build();
        let rb = Compiler::new(hw.clone()).compile(&mut base).unwrap();
        if rb.inserted.is_empty() {
            continue; // no round trip, nothing to chunk
        }
        let sbase = simulate(&base, &rb.order, &hw);
        let slo = sbase.makespan_us * 1.1;

        let throttle = |split_min: u64| SloThrottle {
            split_min_bytes: split_min,
            defer_prefetches: false,
            ..Default::default()
        };
        let mut unsplit = build();
        let ru = Compiler::new(hw.clone())
            .slo_us(slo)
            .pass(throttle(0))
            .verify(true)
            .compile(&mut unsplit)
            .unwrap_or_else(|e| panic!("seed {seed}: unsplit {e}"));
        let su = simulate(&unsplit, &ru.order, &hw);

        let mut split = build();
        let rs = Compiler::new(hw.clone())
            .slo_us(slo)
            .pass(throttle(64 << 20))
            .verify(true)
            .compile(&mut split)
            .unwrap_or_else(|e| panic!("seed {seed}: split {e}"));
        let ss = simulate(&split, &rs.order, &hw);

        assert!(
            ss.peak_device_bytes <= su.peak_device_bytes,
            "seed {seed}: chunking raised peak {} > {}",
            ss.peak_device_bytes,
            su.peak_device_bytes
        );
        assert_eq!(
            ss.dma_bytes, su.dma_bytes,
            "seed {seed}: chunking changed fabric traffic"
        );
        assert!(
            ss.makespan_us <= slo.max(su.makespan_us) * (1.0 + 1e-9),
            "seed {seed}: chunked schedule broke the budget: {} vs {}",
            ss.makespan_us,
            slo
        );
        if rs.chunked > 0 {
            assert!(
                ss.residency_byte_time() < su.residency_byte_time(),
                "seed {seed}: committed chunking must cut byte·time"
            );
        }
    }
}

#[test]
fn p13_incremental_analyses_bit_identical_to_full_recomputation() {
    // (a) Random journalled mutation sequences: after every mutation the
    // AnalysisCache (delta-patching where local, falling back where not)
    // must agree bit for bit with a fresh topo_order_detailed() and a
    // fresh LifetimeAnalysis::run.
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 14_000);
        let mut g = random_graph(&mut rng);
        let mut cache = AnalysisCache::new();
        // Warm the cache so later queries exercise the delta paths.
        cache.topo_order(&g).unwrap();
        cache.lifetimes(&g).unwrap();
        for step in 0..12 {
            let order = g.topo_order_detailed().unwrap();
            match rng.usize(0, 5) {
                0 => {
                    // Append a compute op reading random existing tensors.
                    let out = g.add_tensor(
                        format!("p13.t{}", g.tensors.len()),
                        1 << 20,
                        Tier::Device,
                    );
                    let mut inputs = Vec::new();
                    for _ in 0..rng.usize(0, 3) {
                        inputs.push(rng.usize(0, out));
                    }
                    inputs.sort_unstable();
                    inputs.dedup();
                    g.add_op(
                        format!("p13.op{}", g.ops.len()),
                        OpKind::Compute { flops: 1e9, bytes_accessed: 0 },
                        inputs,
                        vec![out],
                    );
                }
                1 => {
                    // Forward control dep between two already-ordered ops.
                    let i = rng.usize(0, order.len() - 1);
                    let j = rng.usize(i + 1, order.len());
                    g.add_control_dep(order[j], order[i]);
                }
                2 => {
                    // New data edge whose producer precedes the consumer.
                    let j = rng.usize(1, order.len());
                    let i = rng.usize(0, j);
                    if let Some(&t) = g.op(order[i]).outputs.first() {
                        g.add_input(order[j], t);
                    }
                }
                3 => {
                    // Non-local rewire: replace an input with a fresh
                    // producerless tensor (forces the full-recompute
                    // fallback — the differential must still hold).
                    let with_inputs: Vec<usize> = g
                        .ops
                        .iter()
                        .filter(|o| !o.inputs.is_empty())
                        .map(|o| o.id)
                        .collect();
                    if !with_inputs.is_empty() {
                        let op = *rng.choose(&with_inputs);
                        let old = *rng.choose(&g.op(op).inputs.clone());
                        let new = g.add_tensor(
                            format!("p13.sub{}", g.tensors.len()),
                            1 << 16,
                            Tier::Device,
                        );
                        g.replace_input(op, old, new);
                    }
                }
                _ => {
                    // Metadata-only mutations.
                    g.add_tensor(format!("p13.w{}", g.tensors.len()), 1 << 22, Tier::Remote);
                }
            }
            let inc = cache.topo_order(&g).unwrap();
            let full = g.topo_order_detailed().unwrap();
            assert_eq!(*inc, full, "seed {seed} step {step}: topo diverged");
            let inc_lt = cache.lifetimes(&g).unwrap();
            let full_lt = LifetimeAnalysis::run(&g, &full);
            assert_eq!(inc_lt.pos, full_lt.pos, "seed {seed} step {step}: pos diverged");
            assert_eq!(
                inc_lt.lifetimes.len(),
                full_lt.lifetimes.len(),
                "seed {seed} step {step}: lifetime table size"
            );
            for (t, a) in &full_lt.lifetimes {
                let b = &inc_lt.lifetimes[t];
                assert_eq!(a.def_pos, b.def_pos, "seed {seed} step {step} tensor {t}");
                assert_eq!(a.use_pos, b.use_pos, "seed {seed} step {step} tensor {t}");
                assert_eq!(
                    a.max_idle_gap, b.max_idle_gap,
                    "seed {seed} step {step} tensor {t}"
                );
                assert_eq!(
                    a.idle_gap_start, b.idle_gap_start,
                    "seed {seed} step {step} tensor {t}"
                );
            }
        }
        assert!(cache.hits() > 0, "seed {seed}: no query was served incrementally");
    }

    // (b) Windowed re-simulation: SimTrace::resume at any cut must equal
    // the full simulation bit for bit, with and without a speculative
    // extra dep landing in the suffix.
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 15_000);
        let hw = hw(&mut rng);
        let mut g = random_graph(&mut rng);
        let report = Compiler::new(hw.clone())
            .policy(OffloadPolicy { min_bytes: 1 << 18, ..Default::default() })
            .compile(&mut g)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let order = report.order;
        let trace = SimTrace::record(&g, &order, &hw);
        let full = simulate(&g, &order, &hw);
        let assert_same = |r: &hyperoffload::sim::SimResult,
                           f: &hyperoffload::sim::SimResult,
                           what: &str| {
            assert_eq!(
                r.makespan_us.to_bits(),
                f.makespan_us.to_bits(),
                "seed {seed} {what}: makespan"
            );
            assert_eq!(r.peak_device_bytes, f.peak_device_bytes, "seed {seed} {what}: peak");
            assert_eq!(r.dma_bytes, f.dma_bytes, "seed {seed} {what}: dma bytes");
            assert_eq!(
                r.exposed_comm_us.to_bits(),
                f.exposed_comm_us.to_bits(),
                "seed {seed} {what}: exposed comm"
            );
            assert_eq!(r.residency.len(), f.residency.len(), "seed {seed} {what}: residency");
            for (a, b) in r.residency.iter().zip(&f.residency) {
                assert_eq!(a.0.to_bits(), b.0.to_bits(), "seed {seed} {what}: residency t");
                assert_eq!(a.1, b.1, "seed {seed} {what}: residency bytes");
            }
        };
        for cut in [0, order.len() / 3, order.len() / 2, order.len()] {
            let r = trace.resume(cut, &g, &order, &hw, &[]);
            assert_same(&r, &full, &format!("cut {cut}"));
        }
        // Speculative rewrite: one extra dep (o, d) with o in the suffix
        // must match simulating the mutated graph in full.
        let cut = rng.usize(1, order.len() - 1);
        let j = rng.usize(cut, order.len());
        let i = rng.usize(0, j);
        let (o, d) = (order[j], order[i]);
        let windowed = trace.resume(cut, &g, &order, &hw, &[(o, d)]);
        let mut gm = g.clone();
        gm.add_control_dep(o, d);
        let fm = simulate(&gm, &order, &hw);
        assert_same(&windowed, &fm, &format!("extra dep {d}->{o} cut {cut}"));
    }
}

#[test]
fn p6_router_conserves_requests_and_balances() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 5000);
        let n = rng.usize(1, 9);
        let mut router = Router::new(n, RoutePolicy::LeastLoaded);
        let reqs: Vec<Request> = (0..rng.usize(10, 200))
            .map(|i| Request {
                id: i as u64,
                arrival_us: 0.0,
                prompt_tokens: rng.usize(16, 4096),
                gen_tokens: rng.usize(1, 512),
                block_hashes: vec![],
            })
            .collect();
        let parts = router.partition(&reqs);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, reqs.len(), "seed {seed}: requests lost");
        // Least-loaded: max/min outstanding-token imbalance bounded by the
        // largest single request.
        let loads: Vec<u64> = (0..n).map(|i| router.load_of(i)).collect();
        let max_req = reqs
            .iter()
            .map(|r| (r.prompt_tokens + r.gen_tokens) as u64)
            .max()
            .unwrap();
        let spread = loads.iter().max().unwrap() - loads.iter().min().unwrap();
        assert!(spread <= max_req, "seed {seed}: spread {spread} > {max_req}");
    }
}

#[test]
fn p14_prefix_sharing_conserves_pool_bytes_and_is_byte_identical_downstream() {
    // (a) Refcount conservation: the pool ledger is exactly the deduped
    // sum — each manager's private bytes plus the resident shared blocks,
    // each shared block counted once — after *every* operation of a random
    // admit/decode/fork/preempt/retire interleaving across two managers
    // sharing one pool and one index (two replicas of the cluster-wide
    // cache).
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 16_000);
        let hw = hw(&mut rng);
        let kv_per_tok = 64 * 1024u64;
        let bt = NsaConfig::default().block_tokens;
        let block = bt as u64 * kv_per_tok;
        let pool = PoolHandle::new_chunked((16 + rng.gen_range(0, 48)) * block, block);
        let idx = PrefixIndex::new();
        let mk = || {
            KvCacheManager::with_pool_and_index(
                KvPolicy::FullOffload,
                NsaConfig::default(),
                kv_per_tok,
                1 << 30,
                pool.clone(),
                Some(idx.clone()),
            )
        };
        let mut ms = [mk(), mk()];
        let mut live: Vec<(usize, u64)> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..250 {
            match rng.usize(0, 8) {
                0..=2 => {
                    // Admission, mostly templated. Re-admitting a template
                    // an earlier (since-retired, i.e. preempted) sequence
                    // prefilled exercises the requeue path: it must hit the
                    // cache instead of re-reserving the blocks.
                    let hashes = if rng.next_f64() < 0.7 {
                        template_prefix_hashes(rng.gen_range(0, 3), rng.usize(1, 5) * bt, bt)
                    } else {
                        vec![]
                    };
                    let prompt = hashes.len() * bt + rng.usize(1, 200);
                    let m = rng.usize(0, 2);
                    if ms[m].admit_prefix(next_id, prompt, &hashes, &hw).is_ok() {
                        live.push((m, next_id));
                    }
                    next_id += 1;
                }
                3..=5 if !live.is_empty() => {
                    // Decode may fail on pool exhaustion; the ledger must
                    // stay consistent either way.
                    let &(m, id) = rng.choose(&live);
                    let _ = ms[m].decode_step(id, &hw);
                }
                6 if !live.is_empty() => {
                    let &(m, id) = rng.choose(&live);
                    ms[m].fork(id, next_id).unwrap();
                    live.push((m, next_id));
                    next_id += 1;
                }
                7 if !live.is_empty() => {
                    let i = rng.usize(0, live.len());
                    let (m, id) = live.swap_remove(i);
                    ms[m].retire(id).unwrap();
                }
                _ => {}
            }
            let private: u64 = ms.iter().map(|mg| mg.remote_kv_bytes).sum();
            assert_eq!(
                pool.used(),
                private + idx.resident_bytes(),
                "seed {seed}: pool ledger diverged from the deduped sum"
            );
        }
        // Drain: retiring every sequence leaves exactly the cached
        // prefixes, and evicting those empties the pool to zero.
        for (m, id) in live.drain(..) {
            ms[m].retire(id).unwrap();
        }
        let resident = idx.resident_bytes();
        assert_eq!(pool.used(), resident, "seed {seed}: private bytes leaked");
        assert_eq!(idx.evict(&pool, u64::MAX), resident, "seed {seed}: eviction fell short");
        assert_eq!(pool.used(), 0, "seed {seed}: eviction leaked");
        assert!(idx.is_empty(), "seed {seed}");
    }

    // (b) A prefix-hit admission is byte-identical downstream to a cold
    // prefill of the same prompt: the hit blocks never re-prefill, and
    // every subsequent decode step moves the same bytes and charges the
    // same host time.
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 16_500);
        let hw = hw(&mut rng);
        let kv_per_tok = 64 * 1024u64;
        let bt = NsaConfig::default().block_tokens;
        let block = bt as u64 * kv_per_tok;
        let hashes = template_prefix_hashes(seed, rng.usize(1, 6) * bt, bt);
        let prompt = hashes.len() * bt + rng.usize(1, 400);
        let steps = rng.usize(1, 120);
        let run = |warm: bool| {
            let pool = PoolHandle::new_chunked(1 << 40, block);
            let idx = PrefixIndex::new();
            let mut m = KvCacheManager::with_pool_and_index(
                KvPolicy::FullOffload,
                NsaConfig::default(),
                kv_per_tok,
                1 << 30,
                pool.clone(),
                Some(idx.clone()),
            );
            if warm {
                // A sibling prefills the template and retires; the prefix
                // stays index-resident, so the probe admission hits.
                m.admit_prefix(1000, prompt, &hashes, &hw).unwrap();
                m.retire(1000).unwrap();
            }
            let admit = m.admit_prefix(1, prompt, &hashes, &hw).unwrap();
            let costs: Vec<(u64, u64, u64)> = (0..steps)
                .map(|_| {
                    let c = m.decode_step(1, &hw).unwrap();
                    (c.r2d_bytes, c.d2r_bytes, c.cpu_us.to_bits())
                })
                .collect();
            (admit.hit_blocks, admit.cost.d2r_bytes, costs)
        };
        let (cold_hits, cold_d2r, cold_costs) = run(false);
        let (warm_hits, warm_d2r, warm_costs) = run(true);
        assert_eq!(cold_hits, 0, "seed {seed}: cold run must miss");
        assert_eq!(warm_hits, hashes.len(), "seed {seed}: warm run must hit every block");
        assert_eq!(
            warm_d2r,
            cold_d2r - hashes.len() as u64 * block,
            "seed {seed}: hit blocks must not re-prefill"
        );
        assert_eq!(cold_costs, warm_costs, "seed {seed}: decode paths diverged after admission");
    }
}

#[test]
fn p16_tiered_ledger_conserves_bytes_per_tier_under_random_moves() {
    use std::collections::HashMap;

    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 17_000);
        let base = hw(&mut rng);
        let topo = if rng.next_f64() < 0.5 {
            TierTopology::three_tier(&base)
        } else {
            TierTopology::five_tier(&base)
        };
        // A small pool so promotions toward it genuinely fail sometimes;
        // the cold tiers inherit the (huge) topology capacities.
        let pool = PoolHandle::new(rng.gen_range(64, 512) * 1024);
        let ledger = TieredLedger::from_topology(pool, &topo, 1);
        let tiers: Vec<Tier> = ledger.tiers().collect();

        // Reference model: private bytes per tier, and shared entries as
        // key -> (resident tier, bytes, refs).
        let mut private: HashMap<Tier, u64> = tiers.iter().map(|&t| (t, 0)).collect();
        let mut shared: HashMap<u64, (Tier, u64, u64)> = HashMap::new();

        for _ in 0..300 {
            let before = ledger.total_used();
            match rng.usize(0, 10) {
                0..=2 => {
                    // Private reservation on a random tier.
                    let t = *rng.choose(&tiers);
                    let b = rng.gen_range(1, 64 * 1024);
                    if ledger.handle(t).unwrap().try_reserve(b) {
                        *private.get_mut(&t).unwrap() += b;
                    } else {
                        assert_eq!(ledger.total_used(), before, "seed {seed}: partial reserve");
                    }
                }
                3 => {
                    // Private release (never more than the tier holds).
                    let t = *rng.choose(&tiers);
                    if private[&t] > 0 {
                        let b = rng.gen_range(1, private[&t] + 1);
                        ledger.handle(t).unwrap().release(b);
                        *private.get_mut(&t).unwrap() -= b;
                    }
                }
                4..=5 => {
                    // Demotion/promotion of private bytes. Sizes stay
                    // within the model's private holdings — the ledger
                    // itself cannot tell private from shared backing, so
                    // an overdraw against private-only is exercised
                    // separately below with a guaranteed-failing size.
                    let src = *rng.choose(&tiers);
                    let dst = *rng.choose(&tiers);
                    if private[&src] > 0 {
                        let b = rng.gen_range(1, private[&src] + 1);
                        let moved = ledger.move_private(src, dst, b);
                        if moved && src != dst {
                            *private.get_mut(&src).unwrap() -= b;
                            *private.get_mut(&dst).unwrap() += b;
                        } else if !moved {
                            assert_eq!(ledger.total_used(), before, "seed {seed}: partial move");
                        }
                    }
                }
                6 => {
                    // Overdraw: more bytes than the source tier holds at
                    // all. Must fail atomically.
                    let src = *rng.choose(&tiers);
                    let dst = *rng.choose(&tiers);
                    if src != dst {
                        let b = ledger.handle(src).unwrap().used() + 1;
                        assert!(!ledger.move_private(src, dst, b), "seed {seed}: overdraw moved");
                        assert_eq!(ledger.total_used(), before, "seed {seed}: overdraw leaked");
                    }
                }
                7 => {
                    // Shared acquire: attach on the resident tier, or
                    // reserve fresh on a random one.
                    let key = rng.gen_range(0, 6);
                    if let Some(&(t, _, _)) = shared.get(&key) {
                        let r = ledger.handle(t).unwrap().shared_acquire(key, 1);
                        assert_eq!(r, SharedAcquire::Attached, "seed {seed}");
                        shared.get_mut(&key).unwrap().2 += 1;
                    } else {
                        let t = *rng.choose(&tiers);
                        let b = rng.gen_range(1, 32 * 1024);
                        match ledger.handle(t).unwrap().shared_acquire(key, b) {
                            SharedAcquire::Reserved => {
                                shared.insert(key, (t, b, 1));
                            }
                            SharedAcquire::Exhausted => {
                                assert_eq!(ledger.total_used(), before, "seed {seed}")
                            }
                            SharedAcquire::Attached => {
                                panic!("seed {seed}: attached to a key the model never saw")
                            }
                        }
                    }
                }
                8 => {
                    // Shared release on the resident tier; bytes return
                    // only with the last reference.
                    let keys: Vec<u64> = shared.keys().copied().collect();
                    if !keys.is_empty() {
                        let key = *rng.choose(&keys);
                        let (t, _, refs) = shared[&key];
                        let last = ledger.handle(t).unwrap().shared_release(key);
                        assert_eq!(last, refs == 1, "seed {seed}: wrong last-ref signal");
                        if refs == 1 {
                            shared.remove(&key);
                        } else {
                            shared.get_mut(&key).unwrap().2 -= 1;
                        }
                    }
                }
                _ => {
                    // Shared move (demotion/promotion of a cached entry):
                    // bytes and refcount travel together or not at all.
                    let keys: Vec<u64> = shared.keys().copied().collect();
                    if !keys.is_empty() {
                        let key = *rng.choose(&keys);
                        let (t, b, refs) = shared[&key];
                        let dst = *rng.choose(&tiers);
                        let ok = ledger.shared_move(key, t, dst);
                        if ok && dst != t {
                            assert_eq!(
                                ledger.handle(dst).unwrap().shared_refs(key),
                                refs,
                                "seed {seed}: refcount lost in transit"
                            );
                            shared.insert(key, (dst, b, refs));
                        } else if !ok {
                            assert_eq!(ledger.total_used(), before, "seed {seed}: partial move");
                        }
                    }
                }
            }
            // The invariant: every tier's ledger is exactly its modelled
            // private plus shared holdings after every operation.
            for &t in &tiers {
                let on_tier: u64 =
                    shared.values().filter(|&&(st, _, _)| st == t).map(|&(_, b, _)| b).sum();
                let want = private[&t] + on_tier;
                assert_eq!(
                    ledger.handle(t).unwrap().used(),
                    want,
                    "seed {seed}: tier {t:?} ledger diverged from the model"
                );
            }
        }

        // Drain: releasing every holding empties the whole stack.
        for (&t, b) in private.iter() {
            ledger.handle(t).unwrap().release(*b);
        }
        for (&key, &(t, _, refs)) in shared.iter() {
            let h = ledger.handle(t).unwrap();
            for r in 0..refs {
                assert_eq!(h.shared_release(key), r + 1 == refs, "seed {seed}");
            }
        }
        assert_eq!(ledger.total_used(), 0, "seed {seed}: drain leaked");
    }
}

#[test]
fn p17_two_tier_topology_bit_identical_to_legacy_compiles() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 18_000);
        let base = hw(&mut rng);
        let mut legacy = random_graph(&mut rng);
        let mut mirrored = legacy.clone();

        let rl = Compiler::new(base.clone()).verify(true).compile(&mut legacy).unwrap();
        let hw2 = base.clone().with_tiers(TierTopology::two_tier(&base));
        let r2 = Compiler::new(hw2.clone())
            .tier_placement()
            .verify(true)
            .compile(&mut mirrored)
            .unwrap();

        assert_eq!(r2.retiered, 0, "seed {seed}: two-tier stack has nowhere to rehome");
        assert_eq!(rl.order, r2.order, "seed {seed}: schedule diverged");
        assert_eq!(legacy.ops.len(), mirrored.ops.len(), "seed {seed}");
        for (a, b) in legacy.ops.iter().zip(&mirrored.ops) {
            assert_eq!(a.kind, b.kind, "seed {seed}: op {} diverged", a.id);
        }

        let sl = simulate(&legacy, &rl.order, &base);
        let s2 = simulate(&mirrored, &r2.order, &hw2);
        assert_eq!(
            sl.makespan_us.to_bits(),
            s2.makespan_us.to_bits(),
            "seed {seed}: makespan not bit-identical"
        );
        assert_eq!(sl.peak_device_bytes, s2.peak_device_bytes, "seed {seed}");
        assert_eq!(sl.dma_bytes, s2.dma_bytes, "seed {seed}");
        assert_eq!(
            sl.exposed_comm_us.to_bits(),
            s2.exposed_comm_us.to_bits(),
            "seed {seed}: exposed time not bit-identical"
        );
    }
}

#[test]
fn p18_lease_ledger_conserves_harvested_bytes_under_revocation() {
    use std::collections::HashMap;

    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 19_000);
        let n_lenders = rng.usize(2, 6) as u16;
        let lease = LeaseLedger::new();
        // A pool small enough that demotions genuinely fail sometimes
        // (the park-at-peer retry path), large enough that most land.
        let pool = PoolHandle::new(rng.gen_range(64, 512) * 1024);
        let mut cap: HashMap<u16, u64> = HashMap::new();
        for r in 0..n_lenders {
            let c = rng.gen_range(32, 256) * 1024;
            lease.register_lender(r, c);
            cap.insert(r, c);
        }

        // Reference model.
        let mut lent: HashMap<u16, u64> = (0..n_lenders).map(|r| (r, 0)).collect();
        let mut borrowed = 0u64; // Σ bytes ever handed out on lease
        let mut released = 0u64; // Σ bytes returned by retire/preempt
        let mut demoted = 0u64; // Σ bytes revocation moved into the pool
        let mut revocations = 0u64;

        for _ in 0..300 {
            match rng.usize(0, 10) {
                0..=3 => {
                    // Anonymous borrow (admission picks any open lender).
                    let bytes = rng.gen_range(1, 48 * 1024);
                    let before = lease.total_lent();
                    match lease.try_borrow(u16::MAX, bytes) {
                        Some(l) => {
                            *lent.get_mut(&l).unwrap() += bytes;
                            borrowed += bytes;
                            assert!(
                                lent[&l] <= cap[&l],
                                "seed {seed}: lease overdrew lender {l}'s spare capacity"
                            );
                        }
                        None => {
                            assert_eq!(
                                lease.total_lent(),
                                before,
                                "seed {seed}: failed borrow moved bytes"
                            );
                            for r in 0..n_lenders {
                                assert!(
                                    lease.headroom(r) < bytes,
                                    "seed {seed}: lender {r} had room yet the borrow failed"
                                );
                            }
                        }
                    }
                }
                4 => {
                    // Growth borrow against a specific lender.
                    let r = rng.gen_range(0, n_lenders as u64) as u16;
                    let bytes = rng.gen_range(1, 48 * 1024);
                    let had_room = lease.is_open(r) && cap[&r] - lent[&r] >= bytes;
                    let ok = lease.borrow_from(r, bytes);
                    assert_eq!(ok, had_room, "seed {seed}: borrow_from disagreed with the model");
                    if ok {
                        *lent.get_mut(&r).unwrap() += bytes;
                        borrowed += bytes;
                    }
                }
                5 => {
                    // Lender load eases or tightens: toggle openness.
                    let r = rng.gen_range(0, n_lenders as u64) as u16;
                    lease.set_open(r, rng.next_f64() < 0.7);
                }
                6 => {
                    // Borrower retires or is preempted: bytes come home
                    // without touching the pool.
                    let r = rng.gen_range(0, n_lenders as u64) as u16;
                    if lent[&r] > 0 {
                        let bytes = rng.gen_range(1, lent[&r] + 1);
                        lease.release(r, bytes);
                        *lent.get_mut(&r).unwrap() -= bytes;
                        released += bytes;
                    }
                }
                _ => {
                    // Load spike: revoke, then sweep the lease to the pool
                    // in random chunks until done or the pool fills.
                    let r = rng.gen_range(0, n_lenders as u64) as u16;
                    let out = lease.begin_revoke(r);
                    assert_eq!(out, lent[&r], "seed {seed}: revoke saw stale lent bytes");
                    assert!(!lease.is_open(r), "seed {seed}: revoked lender still open");
                    if out > 0 {
                        revocations += 1;
                    }
                    let mut remaining = out;
                    while remaining > 0 {
                        let chunk = rng.gen_range(1, remaining + 1);
                        let pool_before = pool.used();
                        if lease.demote(r, chunk, &pool) {
                            *lent.get_mut(&r).unwrap() -= chunk;
                            demoted += chunk;
                            remaining -= chunk;
                            assert_eq!(
                                pool.used(),
                                pool_before + chunk,
                                "seed {seed}: demoted bytes missed the pool"
                            );
                        } else {
                            // Full pool: the chunk stays parked on lease.
                            assert_eq!(
                                pool.used(),
                                pool_before,
                                "seed {seed}: failed demote leaked"
                            );
                            assert_eq!(
                                lease.lent(r),
                                lent[&r],
                                "seed {seed}: failed demote retired bytes"
                            );
                            break;
                        }
                    }
                }
            }

            // The invariants, after every operation.
            for r in 0..n_lenders {
                assert_eq!(lease.lent(r), lent[&r], "seed {seed}: lender {r} diverged from model");
                assert!(lent[&r] <= cap[&r], "seed {seed}: model overdrew lender {r}");
            }
            let total: u64 = lent.values().sum();
            assert_eq!(lease.total_lent(), total, "seed {seed}: total lent diverged");
            assert_eq!(
                total + demoted,
                borrowed - released,
                "seed {seed}: bytes minted or dropped (lent {total} + demoted {demoted} \
                 != borrowed {borrowed} - released {released})"
            );
            assert_eq!(lease.revoked_bytes(), demoted, "seed {seed}: revoked-byte counter drifted");
            assert_eq!(
                pool.used(),
                demoted,
                "seed {seed}: pool holds a byte revocation never sent"
            );
            assert_eq!(lease.revocations(), revocations, "seed {seed}: revocation count drifted");
            assert!(lease.borrowed_peak() >= total, "seed {seed}: peak below a live total");
        }

        // Drain: every lease comes home one way or the other, and the
        // pool ends holding exactly the revoked bytes — each moved once.
        for r in 0..n_lenders {
            if lent[&r] > 0 {
                lease.release(r, lent[&r]);
                released += lent[&r];
                *lent.get_mut(&r).unwrap() = 0;
            }
            // A demote against an empty lease must be a clean no-op, not
            // a double-free into the pool.
            let pool_before = pool.used();
            assert!(!lease.demote(r, 1, &pool), "seed {seed}: empty lease demoted");
            assert_eq!(pool.used(), pool_before, "seed {seed}: double-free into the pool");
        }
        assert_eq!(lease.total_lent(), 0, "seed {seed}: drain left bytes on lease");
        assert_eq!(demoted, borrowed - released, "seed {seed}: drain broke conservation");
        assert_eq!(pool.used(), demoted, "seed {seed}: pool total wrong after drain");
    }
}
