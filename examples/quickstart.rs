//! Quickstart: the HyperOffload compile pipeline on a small workload.
//!
//! Builds a weight-streaming graph, runs lifetime analysis + cache-operator
//! insertion + Algorithm 1, and prints the before/after timeline — the
//! 60-second tour of the paper's idea.
//!
//! Run: `cargo run --release --example quickstart`

use hyperoffload::graph::GraphBuilder;
use hyperoffload::passes::Compiler;
use hyperoffload::runtime_sched::{simulate_reactive, ReactiveConfig, ReactiveMode};
use hyperoffload::sim::{simulate, HwConfig, MB};
use hyperoffload::util::table::{f, Table};

fn main() {
    let hw = HwConfig::ascend910c_like();

    // A 12-layer model whose weights live in the SuperNode pool: each layer
    // computes ~6 ms and streams a 100 MB weight (3 ms at 33.6 GB/s).
    let (graph, _) = GraphBuilder::chain_with_remote_weights(12, 2e12, 64 * MB, 100 * MB);

    println!("workload: 12 layers, 100 MB pool-resident weights each\n");

    // 1. Reactive runtime (the paper's baseline, Fig. 3a/b).
    let serial = simulate_reactive(&graph, &ReactiveConfig::default(), &hw);
    let runtime_pf = simulate_reactive(
        &graph,
        &ReactiveConfig { mode: ReactiveMode::Prefetch { lookahead: 2 }, compaction_every: 4, compaction_us: 2000.0 },
        &hw,
    );

    // 2. HyperOffload: a compile session — lifetime analysis, cache-op
    //    insertion, Algorithm 1 — with the IR verifier between stages
    //    (Fig. 3c).
    let mut g = graph.clone();
    let report = Compiler::new(hw.clone())
        .verify(true)
        .compile(&mut g)
        .expect("compile session failed");
    let ours = simulate(&g, &report.order, &hw);

    println!(
        "compile: {} cache ops inserted, {} rejected as not profitable, {} moved by Algorithm 1",
        report.inserted.len(),
        report.rejected,
        report.moved
    );
    println!(
        "session: {} passes, {} diagnostics, analysis cache {} hits / {} misses\n",
        report.per_pass.len(),
        report.diagnostics.len(),
        report.cache_hits,
        report.cache_misses
    );

    let mut t = Table::new(
        "execution strategies (same graph, same hardware)",
        &["strategy", "makespan ms", "exposed comm ms", "overlap %"],
    );
    for (name, r) in [
        ("serial / on-demand", &serial),
        ("runtime prefetch", &runtime_pf),
        ("HyperOffload (graph-driven)", &ours),
    ] {
        t.row(&[
            name.into(),
            f(r.makespan_us / 1e3, 2),
            f(r.exposed_comm_us / 1e3, 2),
            f(r.overlap_efficiency() * 100.0, 0),
        ]);
    }
    t.print();

    println!(
        "\nspeedup vs serial: {:.2}x   vs runtime prefetch: {:.2}x",
        serial.makespan_us / ours.makespan_us,
        runtime_pf.makespan_us / ours.makespan_us
    );
}
