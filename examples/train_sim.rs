//! Training case study (§5.1): activation + optimizer-state offload for
//! LLaMA-8B and DeepSeek-V3-like presets across pool bandwidths — the
//! interactive version of Fig. 6.
//!
//! Run: `cargo run --release --example train_sim [llama8b|dsv3]`

use hyperoffload::sim::HwConfig;
use hyperoffload::training::{
    baseline_demand_bytes, baseline_step, hierarchical_step, ModelPreset, ParallelCfg,
};
use hyperoffload::util::table::{f, Table};

fn main() {
    let which = std::env::args().nth(1).unwrap_or_else(|| "llama8b".into());
    let (preset, base_cfg, hier_cfg) = match which.as_str() {
        "dsv3" => (
            ModelPreset::deepseek_v3_like(),
            ParallelCfg::dsv3_baseline(),
            ParallelCfg::dsv3_hier(),
        ),
        _ => (
            ModelPreset::llama8b(),
            ParallelCfg::llama_no2(),
            ParallelCfg::llama_hier(),
        ),
    };

    let hw = HwConfig::ascend910c_like();
    let base = baseline_step(&preset, &base_cfg, &hw);
    println!(
        "{}: baseline {}x{}x{} (recompute {}), step {:.0} ms, demand {:.1} GB",
        preset.name,
        base_cfg.dp,
        base_cfg.tp,
        base_cfg.pp,
        base_cfg.recompute,
        base.total_ms,
        base.demand_bytes / 1e9
    );
    println!(
        "hierarchical layout {}x{}x{} demand {:.1} GB (device holds {:.0} GB)\n",
        hier_cfg.dp,
        hier_cfg.tp,
        hier_cfg.pp,
        baseline_demand_bytes(&preset, &hier_cfg) / 1e9,
        hw.device_capacity as f64 / 1e9
    );

    let mut t = Table::new(
        format!("{} hierarchical step vs pool bandwidth (baseline {:.0} ms)", preset.name, base.total_ms),
        &["D2H GB/s", "compute ms", "exposed ms", "overlapped ms", "total ms", "peak GB", "vs baseline"],
    );
    for bw in [20.0, 33.6, 40.0, 50.0, 60.0, 70.0] {
        let s = hierarchical_step(&preset, &hier_cfg, &hw.clone().with_pool_bandwidth(bw));
        t.row(&[
            f(bw, 1),
            f(s.compute_ms, 0),
            f(s.exposed_d2h_ms, 0),
            f(s.overlapped_d2h_ms, 0),
            f(s.total_ms, 0),
            f(s.peak_bytes / 1e9, 1),
            format!("{:+.1}%", (base.total_ms - s.total_ms) / base.total_ms * 100.0),
        ]);
    }
    t.print();
    println!("\npositive 'vs baseline' = hierarchical faster (paper: parity at 33.6, +5.7–21.5% at 40–70)");
}
