//! High-availability case: failure injection comparing checkpoint-based
//! recovery against SuperNode pool-resident state re-attachment (the
//! paper's cluster-level availability claim, §7.1 baseline (c)).
//!
//! Run: `cargo run --release --example ha_recovery`

use hyperoffload::ha::{
    checkpoint_recovery_s, failure_campaign, pool_recovery_s, CheckpointCfg, StateFootprint,
};
use hyperoffload::sim::{HwConfig, GB};
use hyperoffload::util::table::{f, Table};

fn main() {
    let hw = HwConfig::ascend910c_like();
    let state = StateFootprint { weights: 16 * GB, optimizer: 8 * GB };
    let cfg = CheckpointCfg::default();

    // Single-failure anatomy at three points in the checkpoint interval.
    let mut t = Table::new(
        "single failure: recovery anatomy (LLaMA-8B states, 24 GB)",
        &["failure at step (since ckpt)", "checkpoint path (s)", "pool path (s)"],
    );
    for since in [10u64, 250, 490] {
        t.row(&[
            since.to_string(),
            f(checkpoint_recovery_s(state, &cfg, since), 1),
            f(pool_recovery_s(state, &hw, cfg.restart_overhead_s), 1),
        ]);
    }
    t.print();

    // Campaign: 200 failures uniform over the interval.
    let r = failure_campaign(state, &cfg, &hw, 200, 2026);
    let mut t = Table::new(
        "failure campaign (200 injected failures)",
        &["metric", "checkpoint", "pool-resident"],
    );
    t.row(&[
        "mean recovery (s)".into(),
        f(r.mean_ckpt_recovery_s, 1),
        f(r.mean_pool_recovery_s, 1),
    ]);
    t.row(&[
        "training steps lost".into(),
        r.total_lost_steps_ckpt.to_string(),
        r.total_lost_steps_pool.to_string(),
    ]);
    t.row(&[
        "speedup".into(),
        "1.0x".into(),
        format!("{:.1}x", r.mean_ckpt_recovery_s / r.mean_pool_recovery_s),
    ]);
    t.print();
}
