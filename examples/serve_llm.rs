//! End-to-end driver (deliverable (b) / DESIGN.md §6): load the real
//! AOT-compiled transformer and serve batched requests through the
//! coordinator, baseline vs hierarchical KV policy, reporting latency and
//! throughput. All three layers compose here: the Pallas decode-attention
//! kernel (L1) is inside the jax-lowered decode step (L2), executed from
//! the rust coordinator (L3) via PJRT.
//!
//! Run: `make artifacts && cargo run --release --example serve_llm`

use hyperoffload::coordinator::{Coordinator, ServeConfig};
use hyperoffload::kvcache::KvPolicy;
use hyperoffload::util::table::{f, Table};

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    if !dir.join("meta.txt").exists() {
        anyhow::bail!("artifacts not found in {} — run `make artifacts`", dir.display());
    }

    let mut rows = Vec::new();
    for (name, policy) in [
        ("baseline (KV all-device)", KvPolicy::AllDevice),
        ("hierarchical (KV offload)", KvPolicy::FullOffload),
    ] {
        let cfg = ServeConfig {
            n_requests: 16,
            gen_tokens: 48,
            kv_policy: policy,
            ..ServeConfig::new(dir.clone())
        };
        let coord = Coordinator::load(&cfg.artifacts_dir, cfg.kv_policy)?;
        if rows.is_empty() {
            let s = &coord.model.spec;
            println!(
                "model: {} layers, d={}, {} heads, vocab={}, batch={}, max_seq={}, kv_block={}",
                s.n_layers, s.d_model, s.n_heads, s.vocab, s.batch, s.max_seq, s.kv_block
            );
        }
        let r = coord.serve(&cfg)?;
        println!(
            "[{name}] sample generation: {:?}",
            &r.sample_tokens[..r.sample_tokens.len().min(12)]
        );
        rows.push((name, r));
    }

    let mut t = Table::new(
        "real-execution serving: baseline vs hierarchical (PJRT CPU)",
        &[
            "policy",
            "requests",
            "prefill ms",
            "decode ms/step",
            "tok/s",
            "KV moved MB",
            "KV device peak MB",
        ],
    );
    for (name, r) in &rows {
        t.row(&[
            name.to_string(),
            r.requests.to_string(),
            f(r.prefill_ms.mean, 1),
            f(r.decode_step_ms.mean, 2),
            f(r.throughput_tok_s, 1),
            f(r.kv_transfer_bytes as f64 / 1e6, 1),
            f(r.kv_device_peak as f64 / 1e6, 2),
        ]);
    }
    t.print();

    // The two policies must generate IDENTICAL tokens — offload changes
    // residency, never values.
    assert_eq!(
        rows[0].1.sample_tokens, rows[1].1.sample_tokens,
        "offload changed model outputs!"
    );
    println!("\ntoken streams identical across policies ✓ (offload is value-transparent)");
    Ok(())
}
