//! End-to-end serving driver, upgraded to the cluster era: simulate N
//! devices contending for one SuperNode pool (baseline vs hierarchical
//! KV policy, online routing), and — when built with `--features xla`
//! and AOT artifacts exist — also run the real PJRT-executed model so
//! all three layers compose (Pallas decode-attention kernel inside the
//! jax-lowered step, executed from the rust coordinator).
//!
//! Run: `cargo run --release --example serve_llm [replicas] [artifacts-dir]`
//!
//! (Before the cluster refactor the first argument was the artifacts
//! directory; that moved to the second position.)

use hyperoffload::serving::{
    ClusterConfig, EngineConfig, ModelCost, SimCluster, WorkloadConfig,
};
use hyperoffload::sim::{HwConfig, GB};
use hyperoffload::util::table::{f, Table};

fn main() -> anyhow::Result<()> {
    let n_replicas: usize = match std::env::args().nth(1) {
        None => 4,
        Some(s) => match s.parse() {
            Ok(n) if n > 0 => n,
            _ => anyhow::bail!(
                "usage: serve_llm [replicas >= 1] [artifacts-dir]  (got {s:?})"
            ),
        },
    };

    let model = ModelCost {
        weights_bytes: 8 * GB,
        act_bytes: GB,
        prefill_flops_per_token: 16e9,
        decode_flops_per_token: 16e9,
        kv_bytes_per_token: 64 * 1024,
    };
    let hw = HwConfig::ascend910c_like().with_device_capacity(64 * GB);
    // Half the requests open with one of four shared 1024-token templates
    // (system prompts), so the hierarchical rows exercise the cluster-wide
    // prefix cache; the baseline ignores the hashes.
    let wl = WorkloadConfig {
        n_requests: 48,
        mean_interarrival_us: 15_000.0,
        prompt_min: 1_024,
        prompt_max: 8_192,
        gen_min: 32,
        gen_max: 256,
        seed: 17,
        prefix_share_ratio: 0.5,
        prefix_templates: 4,
        prefix_tokens: 1_024,
        prefix_block_tokens: 64,
        prefix_zipf_s: 0.0,
        burst_phases: 0,
        burst_factor: 1.0,
    }
    .generate();

    let mut t = Table::new(
        format!("simulated cluster serving ({n_replicas} replicas, one shared pool)"),
        &[
            "policy",
            "completed",
            "rejected",
            "preempted",
            "tok/s",
            "p99 e2e ms",
            "exposed xfer ms",
            "fabric stall ms",
            "pool peak GB",
        ],
    );
    let mut compiled_stats: Vec<(String, hyperoffload::serving::ClusterReport)> = Vec::new();
    for (name, engine) in [
        ("baseline (KV all-device)", EngineConfig::baseline(hw.clone(), model.clone())),
        ("hierarchical (KV offload)", EngineConfig::hierarchical(hw.clone(), model.clone())),
        (
            "hierarchical + 15 ms decode SLO",
            EngineConfig::hierarchical_slo(hw.clone(), model.clone(), 15_000.0),
        ),
    ] {
        let r = SimCluster::new(ClusterConfig::new(engine, n_replicas))
            .run(wl.clone())?;
        t.row(&[
            name.into(),
            r.completed.to_string(),
            r.rejected.to_string(),
            r.preempted_events.to_string(),
            f(r.throughput_tok_per_s, 0),
            f(r.e2e_latency_us.p99 / 1e3, 1),
            f(r.exposed_transfer_us / 1e3, 1),
            f(r.fabric_stall_us / 1e3, 1),
            f(r.pool_peak_bytes as f64 / 1e9, 2),
        ]);
        compiled_stats.push((name.to_string(), r));
    }
    t.print();

    // The compiled step-graph path in action: every hierarchical step was
    // lowered into a KV transfer graph and scheduled by the Compiler
    // session (ExecOrder -> SloThrottle -> elide); steady-state decode
    // amortises compilation through the shape-keyed cache, and under the
    // decode SLO the throttle's spill rewrite defers writeback bytes.
    println!("\ncompiled step-graph path (per policy):");
    for (name, r) in &compiled_stats {
        let compiles = r.compile_cache_hits + r.compile_cache_misses;
        if compiles == 0 {
            println!("  {name}: analytic (no KV transfer graphs to compile)");
            continue;
        }
        let splits: u64 = r.per_replica.iter().map(|p| p.chunk_splits).sum();
        println!(
            "  {name}: {} steps compiled, cache hit rate {:.1}% ({} compiles), \
             deferred {:.1} MB, chunk splits {}",
            compiles,
            r.compile_cache_hit_rate() * 100.0,
            r.compile_cache_misses,
            r.slo_deferred_bytes as f64 / 1e6,
            splits,
        );
    }

    // Copy-on-write prefix sharing through the shared pool: hit blocks
    // skip prefill compute, and the pool stores each template once.
    println!("\ncluster-wide prefix cache (per policy):");
    for (name, r) in &compiled_stats {
        if r.prefix_hit_blocks == 0 {
            println!("  {name}: no shared-prefix hits (device-resident KV ignores hashes)");
            continue;
        }
        println!(
            "  {name}: {} block hits, {:.1} GFLOP prefill saved, {:.1} MB pool deduped",
            r.prefix_hit_blocks,
            r.prefill_flops_saved / 1e9,
            r.pool_bytes_deduped as f64 / 1e6,
        );
    }

    real_execution_demo()?;
    Ok(())
}

/// Real-execution serving over the AOT artifacts (PJRT CPU), when the
/// crate is built with the `xla` feature and `make artifacts` has run.
#[cfg(feature = "xla")]
fn real_execution_demo() -> anyhow::Result<()> {
    use hyperoffload::coordinator::{Coordinator, ServeConfig};
    use hyperoffload::kvcache::KvPolicy;

    let dir = std::path::PathBuf::from(
        std::env::args().nth(2).unwrap_or_else(|| "artifacts".into()),
    );
    if !dir.join("meta.txt").exists() {
        println!(
            "\n(no artifacts in {} — run `make artifacts` for the real-execution demo)",
            dir.display()
        );
        return Ok(());
    }

    let mut rows = Vec::new();
    for (name, policy) in [
        ("baseline (KV all-device)", KvPolicy::AllDevice),
        ("hierarchical (KV offload)", KvPolicy::FullOffload),
    ] {
        let cfg = ServeConfig {
            n_requests: 16,
            gen_tokens: 48,
            kv_policy: policy,
            ..ServeConfig::new(dir.clone())
        };
        let coord = Coordinator::load(&cfg.artifacts_dir, cfg.kv_policy)?;
        if rows.is_empty() {
            let s = &coord.model.spec;
            println!(
                "\nmodel: {} layers, d={}, {} heads, vocab={}, batch={}, max_seq={}, kv_block={}",
                s.n_layers, s.d_model, s.n_heads, s.vocab, s.batch, s.max_seq, s.kv_block
            );
        }
        let r = coord.serve(&cfg)?;
        println!(
            "[{name}] sample generation: {:?}",
            &r.sample_tokens[..r.sample_tokens.len().min(12)]
        );
        rows.push((name, r));
    }

    let mut t = Table::new(
        "real-execution serving: baseline vs hierarchical (PJRT CPU)",
        &[
            "policy",
            "requests",
            "prefill ms",
            "decode ms/step",
            "tok/s",
            "KV moved MB",
            "KV device peak MB",
        ],
    );
    for (name, r) in &rows {
        t.row(&[
            name.to_string(),
            r.requests.to_string(),
            f(r.prefill_ms.mean, 1),
            f(r.decode_step_ms.mean, 2),
            f(r.throughput_tok_s, 1),
            f(r.kv_transfer_bytes as f64 / 1e6, 1),
            f(r.kv_device_peak as f64 / 1e6, 2),
        ]);
    }
    t.print();

    // The two policies must generate IDENTICAL tokens — offload changes
    // residency, never values.
    assert_eq!(
        rows[0].1.sample_tokens, rows[1].1.sample_tokens,
        "offload changed model outputs!"
    );
    println!("\ntoken streams identical across policies ✓ (offload is value-transparent)");
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn real_execution_demo() -> anyhow::Result<()> {
    println!("\n(build with --features xla for the real PJRT execution demo)");
    Ok(())
}
