//! Fig. 6(a) — LLaMA-8B end-to-end training step breakdown vs D2H
//! bandwidth: exposed D2H, overlapped D2H, and computation/other, against
//! the 2/2/2 baseline (5200 ms row of Table 1).
//!
//! Paper: parity with baseline at the measured 33.6 GB/s; +5.7%–21.5%
//! improvement over 40–70 GB/s as execution-order optimization hides the
//! remaining traffic.

use hyperoffload::sim::HwConfig;
use hyperoffload::training::{baseline_step, hierarchical_step, ModelPreset, ParallelCfg};
use hyperoffload::util::table::{f, Table};

fn main() {
    let hw0 = HwConfig::ascend910c_like();
    let m = ModelPreset::llama8b();
    let base = baseline_step(&m, &ParallelCfg::llama_no2(), &hw0);
    let hier_cfg = ParallelCfg::llama_hier();

    println!(
        "baseline (Table 1 No.2): {:.0} ms | hierarchical layout 8/1/1, batch 2, GBS 16",
        base.total_ms
    );

    let mut t = Table::new(
        "Fig.6(a) — LLaMA-8B step breakdown vs D2H bandwidth",
        &["D2H GB/s", "exposed D2H ms", "overlapped D2H ms", "compute+other ms",
          "total ms", "vs baseline", "peak GB"],
    );
    for bw in [20.0, 33.6, 40.0, 50.0, 60.0, 70.0] {
        let s = hierarchical_step(&m, &hier_cfg, &hw0.clone().with_pool_bandwidth(bw));
        let other = s.total_ms - s.exposed_d2h_ms - s.compute_ms;
        t.row(&[
            f(bw, 1),
            f(s.exposed_d2h_ms, 0),
            f(s.overlapped_d2h_ms, 0),
            f(s.compute_ms + other.max(0.0), 0),
            f(s.total_ms, 0),
            format!("{:+.1}%", (base.total_ms - s.total_ms) / base.total_ms * 100.0),
            f(s.peak_bytes / 1e9, 1),
        ]);
    }
    t.print();
    println!(
        "\npaper shape: ~parity at 33.6 GB/s, +5.7%..+21.5% at 40-70 GB/s; exposed\n\
         communication progressively eliminated as bandwidth rises."
    );
}
