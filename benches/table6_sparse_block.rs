//! Table 6 — inference breakdown in the sparse-block scenario (the
//! memory-inclusive view of Table 5's run).
//!
//! Paper: peak memory 58428 MB -> 45828 MB (21.57%); prefill predict time
//! 120.098 s -> 115.186 s (4.09%); decode 0.117 -> 0.146 s (-25.47%);
//! total 177.373 -> 177.109 s (0.15%).

use hyperoffload::kvcache::NsaConfig;
use hyperoffload::serving::{EngineConfig, ModelCost, SimServingEngine, WorkloadConfig};
use hyperoffload::sim::HwConfig;
use hyperoffload::util::table::{f, pct, Table};

fn main() {
    let model = ModelCost::dsv3_nsa_like();
    let mut hw = HwConfig::ascend910c_like();
    hw.device_capacity = 64_000_000_000;

    // Longer prompts than Table 5 (the paper's sparse-block run carries
    // real KV mass — peak 58.4 GB), same coarse-block setting.
    let wl = WorkloadConfig {
        n_requests: 16,
        mean_interarrival_us: 0.0,
        prompt_min: 12_000,
        prompt_max: 24_000,
        gen_min: 64,
        gen_max: 192,
        seed: 23,
        prefix_share_ratio: 0.0,
        prefix_templates: 0,
        prefix_tokens: 0,
        prefix_block_tokens: 64,
        prefix_zipf_s: 0.0,
        burst_phases: 0,
        burst_factor: 1.0,
    }
    .generate();

    let base = SimServingEngine::new(EngineConfig {
        max_batch: 2,
        ..EngineConfig::baseline(hw.clone(), model.clone())
    })
    .run(wl.clone())
    .unwrap();
    let hier = SimServingEngine::new(EngineConfig {
        max_batch: 2,
        nsa: NsaConfig::default().coarse(4),
        ..EngineConfig::hierarchical(hw.clone(), model.clone())
    })
    .run(wl)
    .unwrap();

    let mut t = Table::new(
        "Table 6 — sparse-block scenario breakdown",
        &["metric", "baseline", "hierarchical", "change", "paper"],
    );
    t.row(&[
        "peak memory (MB)".into(),
        f(base.peak_device_bytes as f64 / 1e6, 0),
        f(hier.peak_device_bytes as f64 / 1e6, 0),
        pct(hier.peak_device_bytes as f64, base.peak_device_bytes as f64),
        "58428 -> 45828 (21.57%)".into(),
    ]);
    t.row(&[
        "prefill predict time (s)".into(),
        f(base.prefill_latency_us.mean / 1e6, 2),
        f(hier.prefill_latency_us.mean / 1e6, 2),
        pct(hier.prefill_latency_us.mean, base.prefill_latency_us.mean),
        "4.09% faster".into(),
    ]);
    t.row(&[
        "decode predict time (s/token)".into(),
        f(base.decode_per_token_us.mean / 1e6, 4),
        f(hier.decode_per_token_us.mean / 1e6, 4),
        pct(hier.decode_per_token_us.mean, base.decode_per_token_us.mean),
        "-25.47%".into(),
    ]);
    t.row(&[
        "total time (s)".into(),
        f(base.total_time_us / 1e6, 2),
        f(hier.total_time_us / 1e6, 2),
        pct(hier.total_time_us, base.total_time_us),
        "0.15%".into(),
    ]);
    t.print();
}
