//! Table 4 — performance and stability in long-sequence inference near
//! device-memory capacity.
//!
//! Paper: defragmentation events 57 -> 0; prefill latency 129.33 s ->
//! 99.41 s (-23.13%); end-to-end 187.21 s -> 161.41 s (-13.78%).
//!
//! Mechanism: the baseline's device-resident KV churns a fragmenting
//! allocator; every compaction stalls the prefill path. Offloading KV to
//! the pool removes the pressure entirely. Includes the defrag-policy
//! ablation DESIGN.md lists (compaction vs hard-OOM rejection).

use hyperoffload::kvcache::KvPolicy;
use hyperoffload::serving::{EngineConfig, ModelCost, SimServingEngine, WorkloadConfig};
use hyperoffload::sim::HwConfig;
use hyperoffload::util::table::{f, pct, Table};

fn main() {
    let model = ModelCost::dsv3_nsa_like();
    let mut hw = HwConfig::ascend910c_like();
    hw.device_capacity = 64_000_000_000;

    // Near-capacity churn: streams of long, uneven prompts; retirements
    // punch holes the next admit cannot reuse contiguously.
    let wl = WorkloadConfig {
        n_requests: 48,
        mean_interarrival_us: 0.0,
        prompt_min: 20_000,
        prompt_max: 32_000,
        gen_min: 128,
        gen_max: 384,
        seed: 11,
        prefix_share_ratio: 0.0,
        prefix_templates: 0,
        prefix_tokens: 0,
        prefix_block_tokens: 64,
        prefix_zipf_s: 0.0,
        burst_phases: 0,
        burst_factor: 1.0,
    }
    .generate();

    let base = SimServingEngine::new(EngineConfig {
        max_batch: 2,
        ..EngineConfig::baseline(hw.clone(), model.clone())
    })
    .run(wl.clone())
    .unwrap();
    let hier = SimServingEngine::new(EngineConfig {
        max_batch: 2,
        ..EngineConfig::hierarchical(hw.clone(), model.clone())
    })
    .run(wl)
    .unwrap();

    let mut t = Table::new(
        "Table 4 — long-sequence inference near capacity",
        &["metric", "baseline", "hierarchical", "change", "paper"],
    );
    t.row(&[
        "defragmentation events".into(),
        base.defrag_events.to_string(),
        hier.defrag_events.to_string(),
        if hier.defrag_events == 0 { "eliminated".into() } else { "present".into() },
        "57 -> 0".into(),
    ]);
    t.row(&[
        "prefill latency (s, mean)".into(),
        f(base.prefill_latency_us.mean / 1e6, 2),
        f(hier.prefill_latency_us.mean / 1e6, 2),
        pct(hier.prefill_latency_us.mean, base.prefill_latency_us.mean),
        "-23.13%".into(),
    ]);
    t.row(&[
        "end-to-end latency (s, mean)".into(),
        f(base.e2e_latency_us.mean / 1e6, 2),
        f(hier.e2e_latency_us.mean / 1e6, 2),
        pct(hier.e2e_latency_us.mean, base.e2e_latency_us.mean),
        "-13.78%".into(),
    ]);
    t.row(&[
        "rejected/preempted requests".into(),
        base.rejected_requests.to_string(),
        hier.rejected_requests.to_string(),
        "".into(),
        "".into(),
    ]);
    t.print();
}
