//! Ablation — Algorithm 1 cost-model terms (DESIGN.md §5): exposed-latency
//! term only, residency term only, both (default), neither (program
//! order). Shows both terms are necessary: latency-only prefetches early
//! (residency up), residency-only prefetches late (stalls), the combined
//! cost gets both.

use hyperoffload::graph::GraphBuilder;
use hyperoffload::passes::{prefetch_insert, refine, Compiler, ExecOrderConfig, OffloadPolicy};
use hyperoffload::sim::{simulate, HwConfig, MB};
use hyperoffload::util::table::{f, Table};

fn main() {
    let hw = HwConfig::ascend910c_like();

    let variants = [
        ("program order (no Algorithm 1)", None),
        ("latency term only", Some(ExecOrderConfig { residency_term: false, ..Default::default() })),
        ("residency term only", Some(ExecOrderConfig { latency_term: false, ..Default::default() })),
        ("both terms (default)", Some(ExecOrderConfig::default())),
    ];

    let mut t = Table::new(
        "ablation — Algorithm 1 cost model terms",
        &["variant", "makespan ms", "exposed ms", "peak MB", "residency GB*ms", "moved"],
    );

    for (name, cfg) in variants {
        // Fresh workload per variant (compile mutates the graph).
        let (mut g, _) = GraphBuilder::chain_with_remote_weights(16, 4e12, 32 * MB, 300 * MB);
        let (order, moved) = match &cfg {
            None => {
                // Insertion only; simulate the raw topological order.
                let order = g.topo_order().unwrap();
                prefetch_insert::run(&mut g, &order, &hw, &OffloadPolicy::default());
                (g.topo_order().unwrap(), 0)
            }
            Some(c) => {
                let order0 = g.topo_order().unwrap();
                prefetch_insert::run(&mut g, &order0, &hw, &OffloadPolicy::default());
                let r = refine(&mut g, &hw, c);
                (r.order, r.moved)
            }
        };
        let sim = simulate(&g, &order, &hw);
        t.row(&[
            name.into(),
            f(sim.makespan_us / 1e3, 2),
            f(sim.exposed_comm_us / 1e3, 2),
            f(sim.peak_device_bytes as f64 / 1e6, 0),
            f(sim.residency_byte_time() / 1e12, 2),
            moved.to_string(),
        ]);
    }
    t.print();

    // Alpha/beta sensitivity.
    let mut t = Table::new(
        "alpha/beta weight sweep (default alpha=beta=1)",
        &["alpha", "beta", "makespan ms", "residency GB*ms"],
    );
    for (a, b) in [(1.0, 0.01), (1.0, 0.1), (1.0, 1.0), (1.0, 10.0), (0.1, 1.0)] {
        let (mut g, _) = GraphBuilder::chain_with_remote_weights(16, 4e12, 32 * MB, 300 * MB);
        let report = Compiler::new(hw.clone())
            .exec(ExecOrderConfig { alpha: a, beta: b, ..Default::default() })
            .compile(&mut g)
            .unwrap();
        let sim = simulate(&g, &report.order, &hw);
        t.row(&[
            f(a, 2),
            f(b, 2),
            f(sim.makespan_us / 1e3, 2),
            f(sim.residency_byte_time() / 1e12, 2),
        ]);
    }
    t.print();
}
