//! Fig. 4 — communication-overlap strategies: the same operator set under
//! (a) too-late prefetching (stalls, low memory), (b) too-early
//! prefetching (no stalls, high residency), (c) Algorithm 1's optimized
//! order (no stalls, minimal residency). Also serves as the
//! prefetch-distance ablation DESIGN.md calls out.

use hyperoffload::graph::{Graph, GraphBuilder, OpId, Tier};
use hyperoffload::passes::{refine, Compiler, ExecOrderConfig};
use hyperoffload::sim::{simulate, HwConfig, SimResult, MB};
use hyperoffload::util::table::{f, Table};

/// Build the Fig. 4 workload with each prefetch ANCHORED `k(i)` compute
/// ops before its consumer (control dep pins the issue point, exactly how
/// the compiler materialises an order choice). Ops 5..10 each consume a
/// 400 MB pool weight; ops are 18.75 ms, transfers 12.5 ms.
fn workload(anchor: impl Fn(usize) -> usize) -> (Graph, Vec<OpId>) {
    let mut b = GraphBuilder::new();
    let mut prev = None;
    let mut computes: Vec<OpId> = Vec::new();
    let mut pending: Vec<(usize, OpId)> = Vec::new(); // (consumer idx, pf)
    let mut pfs = Vec::new();
    for i in 0..10 {
        let t = b.tensor(&format!("a{i}"), 8 * MB, Tier::Device);
        let mut inputs = prev.map(|p| vec![p]).unwrap_or_default();
        if i >= 5 {
            let w = b.tensor(&format!("w{i}"), 400 * MB, Tier::Remote);
            let pf = b.prefetch(&format!("pf{i}"), w);
            let fire = anchor(i);
            if fire > 0 {
                if let Some(&a) = computes.get(fire - 1) {
                    b.dep(pf, a);
                }
            }
            pfs.push(pf);
            inputs.push(w);
            pending.push((i, pf));
        }
        let o = b.compute(&format!("c{i}"), 6e12, 8 * MB, inputs, vec![t]);
        computes.push(o);
        prev = Some(t);
    }
    let mut g = b.build();
    for (i, pf) in pending {
        g.add_control_dep(computes[i], pf);
    }
    (g, pfs)
}

fn run(anchor: impl Fn(usize) -> usize) -> SimResult {
    let hw = HwConfig::ascend910c_like();
    let (g, _) = workload(anchor);
    let order = g.topo_order().unwrap();
    simulate(&g, &order, &hw)
}

fn main() {
    let hw = HwConfig::ascend910c_like();

    // (a) too late: fire at the consumer. (b) too early: fire at t=0.
    let late_r = run(|i| i);
    let early_r = run(|_| 0);

    // (c) Algorithm 1: start from unanchored prefetches and let the pass
    // choose + anchor positions.
    let (mut g, _) = workload_unanchored();
    let refined = refine(&mut g, &hw, &ExecOrderConfig::default());
    let opt_r = simulate(&g, &refined.order, &hw);

    let mut t = Table::new(
        "Fig.4 — prefetch placement strategies (same operators)",
        &["strategy", "makespan ms", "exposed ms", "peak MB", "residency GB*ms"],
    );
    for (name, r) in [
        ("(a) too late (stalls)", &late_r),
        ("(b) too early (residency)", &early_r),
        ("(c) Algorithm 1", &opt_r),
    ] {
        t.row(&[
            name.into(),
            f(r.makespan_us / 1e3, 2),
            f(r.exposed_comm_us / 1e3, 2),
            f(r.peak_device_bytes as f64 / 1e6, 0),
            f(r.residency_byte_time() / 1e12, 2),
        ]);
    }
    t.print();

    println!("\nprefetch-distance sweep (fire k ops ahead of the consumer):");
    let mut t = Table::new(
        "ablation: fixed prefetch distance",
        &["k", "makespan ms", "exposed ms", "peak MB"],
    );
    for k in 0..=5usize {
        let r = run(|i| i.saturating_sub(k));
        t.row(&[
            k.to_string(),
            f(r.makespan_us / 1e3, 2),
            f(r.exposed_comm_us / 1e3, 2),
            f(r.peak_device_bytes as f64 / 1e6, 0),
        ]);
    }
    t.print();
    println!(
        "\nexpected shape: (a) exposes latency at low memory, (b) hides it at high\n\
         residency, (c) matches (b)'s speed at (a)-like residency."
    );

    // ElideRedundantTransfers (session-API extensibility proof): on the
    // offload round-trip workload the insertion pass stores/prefetches six
    // 256 MB activations through the pool, but the 96 GB device never
    // needed the room — the pass collapses every round trip to plain
    // residency, zeroing fabric traffic at unchanged makespan.
    let mk = || GraphBuilder::fwd_bwd_chain(6, 256 * MB, 8e12, 24, 2e12);
    let mut g_default = mk();
    let r_default = Compiler::new(hw.clone()).compile(&mut g_default).expect("compile");
    let s_default = simulate(&g_default, &r_default.order, &hw);
    let mut g_elide = mk();
    let r_elide = Compiler::new(hw.clone())
        .elide_redundant_transfers()
        .compile(&mut g_elide)
        .expect("compile");
    let s_elide = simulate(&g_elide, &r_elide.order, &hw);

    println!();
    let mut t = Table::new(
        "ElideRedundantTransfers — fabric traffic on the offload round-trip workload",
        &["pipeline", "transferred MB", "makespan ms", "peak MB", "elided"],
    );
    for (name, r, s) in [
        ("default", &r_default, &s_default),
        ("default + elide", &r_elide, &s_elide),
    ] {
        t.row(&[
            name.into(),
            f(s.dma_bytes as f64 / 1e6, 0),
            f(s.makespan_us / 1e3, 2),
            f(s.peak_device_bytes as f64 / 1e6, 0),
            r.elided.to_string(),
        ]);
    }
    t.print();
}

/// Same workload with NO anchors (Algorithm 1 decides from scratch).
fn workload_unanchored() -> (Graph, Vec<OpId>) {
    let mut b = GraphBuilder::new();
    let mut prev = None;
    let mut computes: Vec<OpId> = Vec::new();
    let mut pending: Vec<(usize, OpId)> = Vec::new();
    let mut pfs = Vec::new();
    for i in 0..10 {
        let t = b.tensor(&format!("a{i}"), 8 * MB, Tier::Device);
        let mut inputs = prev.map(|p| vec![p]).unwrap_or_default();
        if i >= 5 {
            let w = b.tensor(&format!("w{i}"), 400 * MB, Tier::Remote);
            let pf = b.prefetch(&format!("pf{i}"), w);
            pfs.push(pf);
            inputs.push(w);
            pending.push((i, pf));
        }
        let o = b.compute(&format!("c{i}"), 6e12, 8 * MB, inputs, vec![t]);
        computes.push(o);
        prev = Some(t);
    }
    let mut g = b.build();
    for (i, pf) in pending {
        g.add_control_dep(computes[i], pf);
    }
    (g, pfs)
}
