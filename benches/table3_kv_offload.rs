//! Table 3 — effect of KV-cache offloading on memory footprint and maximum
//! sequence length (DeepSeek-V3 + NSA setting).
//!
//! Paper: peak device memory 61.2 GB -> 45.0 GB (~-26%, ~= the KV size);
//! max sequence length 71k -> 123k tokens (~1.73x).
//!
//! Two views: a closed-form capacity model (the numbers of the table) and
//! a simulated serving run confirming the engine realises them.

use hyperoffload::kvcache::KvPolicy;
use hyperoffload::serving::{EngineConfig, ModelCost, SimServingEngine, WorkloadConfig};
use hyperoffload::sim::HwConfig;
use hyperoffload::util::table::{f, Table};

fn main() {
    // DSv3+NSA per-device calibration (DESIGN.md §2): 45.0 GB non-KV
    // (weights + activations), 228 KiB KV per token, 64 GB device.
    let model = ModelCost::dsv3_nsa_like();
    let mut hw = HwConfig::ascend910c_like();
    hw.device_capacity = 64_000_000_000; // 64 GB (decimal, as the paper reports)

    let non_kv = (model.weights_bytes + model.act_bytes) as f64;
    let kv_tok = model.kv_bytes_per_token as f64;
    let budget = hw.device_capacity as f64 - non_kv;
    // Fragmentation keeps ~15% of the KV budget unusable in steady state
    // (the §7.3.2 defrag story is the same effect dynamically).
    let usable = 0.85;
    let smax_base = (budget * usable / kv_tok) as u64;
    let peak_base = non_kv + smax_base as f64 * kv_tok;

    // Hierarchical: KV fully pool-resident; device holds only the NSA
    // working set (inside the activation slack). Max length is bounded by
    // the per-sequence pool quota (28.7 GB of the per-device pool share).
    let pool_quota = 28_700_000_000f64;
    let smax_hier = (pool_quota / kv_tok) as u64;
    let peak_hier = non_kv;

    let mut t = Table::new(
        "Table 3 — KV offload: memory footprint and max sequence length",
        &["configuration", "peak device GB", "max seq (tokens)", "paper"],
    );
    t.row(&[
        "baseline (KV on device)".into(),
        f(peak_base / 1e9, 1),
        format!("{}k", smax_base / 1000),
        "61.2 GB / 71k".into(),
    ]);
    t.row(&[
        "hierarchical memory".into(),
        f(peak_hier / 1e9, 1),
        format!("{}k", smax_hier / 1000),
        "45.0 GB / 123k".into(),
    ]);
    t.row(&[
        "relative change".into(),
        format!("{:+.0}%", (peak_hier - peak_base) / peak_base * 100.0),
        format!("{:.2}x", smax_hier as f64 / smax_base as f64),
        "~-26% / ~1.73x".into(),
    ]);
    t.print();

    // Engine confirmation: run both policies on a 60k-token workload.
    let wl = WorkloadConfig::long_sequence(2, 60_000, 128, 5).generate();
    let base = SimServingEngine::new(EngineConfig::baseline(hw.clone(), model.clone()))
        .run(wl.clone())
        .unwrap();
    let hier = SimServingEngine::new(EngineConfig::hierarchical(hw.clone(), model.clone()))
        .run(wl)
        .unwrap();

    let mut t = Table::new(
        "engine confirmation (2 x 60k-token requests)",
        &["policy", "peak device GB", "KV moved GB", "rejected"],
    );
    t.row(&[
        "baseline".into(),
        f(base.peak_device_bytes as f64 / 1e9, 1),
        f(base.kv_transfer_bytes as f64 / 1e9, 1),
        base.rejected_requests.to_string(),
    ]);
    t.row(&[
        "hierarchical".into(),
        f(hier.peak_device_bytes as f64 / 1e9, 1),
        f(hier.kv_transfer_bytes as f64 / 1e9, 1),
        hier.rejected_requests.to_string(),
    ]);
    t.print();
    println!(
        "\npeak reduction from the engine: {:.0}% (paper ~-26%).",
        (1.0 - hier.peak_device_bytes as f64 / base.peak_device_bytes as f64) * 100.0
    );
}
