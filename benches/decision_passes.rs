//! Decision passes on a link-saturated training step — the perf
//! trajectory of the recompute-vs-offload and SLO-throttle decisions.
//!
//! Workload: the Table-1 LLaMA-8B 8/1/1 hierarchical layout with
//! recomputation enabled, on a 48 GB device (so capacity-aware elision
//! keeps the activation round trips) and a 5 GB/s device↔pool link (so
//! those round trips are thoroughly exposed). Four pipeline stacks are
//! compared: offload-only, +capacity-aware elision, +recompute-vs-offload,
//! +SLO throttling.
//!
//! Besides the human-readable table, the run emits
//! `BENCH_decision_passes.json` — machine-readable makespan / peak-bytes /
//! traffic per configuration — so CI can track the perf trajectory.

use hyperoffload::sim::{HwConfig, GB};
use hyperoffload::training::{
    hierarchical_step_with, ModelPreset, ParallelCfg, StepBreakdown, StepOptions,
};
use hyperoffload::util::table::{f, Table};

fn hw() -> HwConfig {
    HwConfig::ascend910c_like()
        .with_pool_bandwidth(5.0)
        .with_device_capacity(48 * GB)
}

fn main() {
    let model = ModelPreset::llama8b();
    let par = ParallelCfg { recompute: true, ..ParallelCfg::llama_hier() };

    let offload_only =
        StepOptions { recompute: false, elide: false, ..StepOptions::for_par(&par) };
    let elide = StepOptions { recompute: false, ..StepOptions::for_par(&par) };
    let recompute = StepOptions::for_par(&par);

    let base = hierarchical_step_with(&model, &par, &hw(), &offload_only);
    let rows: Vec<(&str, StepBreakdown)> = vec![
        ("offload-only", base.clone()),
        ("+elide", hierarchical_step_with(&model, &par, &hw(), &elide)),
        ("+recompute", hierarchical_step_with(&model, &par, &hw(), &recompute)),
        (
            "+recompute+throttle",
            hierarchical_step_with(
                &model,
                &par,
                &hw(),
                &StepOptions { step_slo_ms: Some(base.total_ms), ..StepOptions::for_par(&par) },
            ),
        ),
    ];

    let mut t = Table::new(
        "decision passes, LLaMA-8B 8/1/1 recompute-on, 5 GB/s link, 48 GB device",
        &[
            "pipeline",
            "step ms",
            "vs offload-only",
            "recompute ms",
            "exposed ms",
            "peak GB",
        ],
    );
    for (name, s) in &rows {
        t.row(&[
            (*name).into(),
            f(s.total_ms, 1),
            hyperoffload::util::table::pct(s.total_ms, base.total_ms),
            f(s.recompute_ms, 1),
            f(s.exposed_d2h_ms, 1),
            f(s.peak_bytes / 1e9, 2),
        ]);
    }
    t.print();

    // Machine-readable trajectory for CI.
    let mut json = String::from("{\n  \"bench\": \"decision_passes\",\n  \"rows\": [\n");
    for (i, (name, s)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"config\": \"{name}\", \"makespan_ms\": {:.3}, \"peak_bytes\": {:.0}, \
             \"recompute_ms\": {:.3}, \"exposed_ms\": {:.3}}}{}\n",
            s.total_ms,
            s.peak_bytes,
            s.recompute_ms,
            s.exposed_d2h_ms,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let path = "BENCH_decision_passes.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }

    println!(
        "\nthe insertion pass can only offload; on a saturated link its round\n\
         trips expose. elision keeps what fits resident (capacity-aware),\n\
         recompute replays cheap producers instead of transferring, and the\n\
         throttle spends any SLO slack deferring/splitting what remains."
    );
}
