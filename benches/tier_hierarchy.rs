//! Tier-hierarchy ablation: the same near-capacity long-context trace
//! served by a 2-tier stack (device + pool, demotion impossible — cold
//! prefixes are evicted) and a 3-tier stack (device + pool + DRAM, cold
//! prefixes demote below the pool and admissions re-attach to the
//! demoted copies).
//!
//! The trace is sized so live KV demand brushes pool capacity:
//!
//! * pool: 672 x 2 MiB KV blocks = 1344 MiB
//! * 4 shared templates of 8192 tokens   = 256 MiB each (zipf-reused)
//! * per-request private suffix of 8192 tokens = 256 MiB
//! * 2048 generated tokens               =  64 MiB growth per sequence
//! * max_batch 4 -> live private demand peaks at 4 x 320 MiB = 1280 MiB
//!
//! Three phases, spaced so each is deterministic:
//!
//! 1. **warm** — the first request of every distinct template runs
//!    serially, materialising the templates in the pool.
//! 2. **squeeze** — one unshared request whose prompt reserves the whole
//!    pool. The cold templates must make way: the 2-tier row *evicts*
//!    them (gone), the 3-tier row *demotes* them to DRAM (preserved).
//! 3. **bulk** — the zipf-shared load arrives faster than it drains and
//!    saturates the batch. 3-tier admissions hit the DRAM-resident
//!    templates (cold fetches, zero pool charge), so live pool demand
//!    stays at the 1280 MiB private ceiling and the pool never fills
//!    with all-live bytes. 2-tier admissions re-prefill each template
//!    into the pool, pinning it live; 1280 + 256 MiB > 1344 MiB, so
//!    growth finds the pool exhausted with nothing cold to evict and
//!    the device-spill valve prices the overflow in peak HBM.
//!
//! Asserted acceptance criteria (ISSUE 9): the 3-tier row finishes the
//! identical trace with strictly lower peak device bytes, nonzero cold
//! fetch traffic, more prefix hits, and P99 e2e within 1.5x of 2-tier —
//! peak-HBM reduction at bounded tail regression. The 2-tier row's cold
//! fetch volume must stay exactly zero (the degenerate stack never
//! touches a cold tier). A third row runs the full 5-tier ITME pyramid
//! (device/pool/DRAM/CXL/SSD): on this DRAM-resident trace the extra
//! CXL and SSD levels must carry the same wins — deeper standby tiers
//! cost nothing until something actually cools far enough to reach them.
//!
//! Besides the table the run emits `BENCH_tier_hierarchy.json` for CI
//! (schema-checked against the committed snapshot at
//! `benches/snapshots/BENCH_tier_hierarchy.json`). Pass `tiny` as the
//! first argument for the CI-sized workload.

use hyperoffload::serving::{
    EngineConfig, ModelCost, Request, ServingReport, SimServingEngine, WorkloadConfig,
};
use hyperoffload::sim::{HwConfig, TierTopology, GB, MB};
use hyperoffload::util::table::{f, Table};

/// One KV block: 64 tokens x 32 KiB/token.
const BLOCK: u64 = 2 * MB;
/// Pool capacity in KV blocks (1344 MiB) — sized between the 3-tier live
/// ceiling (1280 MiB of private KV) and the 2-tier one (private plus at
/// least one live 256 MiB template).
const POOL_CHUNKS: u64 = 672;
/// Squeeze prompt: reserves every pool chunk (the last one partially, so
/// its single generated token needs no growth block).
const SQUEEZE_TOKENS: usize = POOL_CHUNKS as usize * 64 - 32;

fn hw() -> HwConfig {
    let mut hw = HwConfig::ascend910c_like().with_device_capacity(16 * GB);
    hw.remote_capacity = POOL_CHUNKS * BLOCK;
    hw
}

fn model() -> ModelCost {
    ModelCost {
        weights_bytes: 4 * GB,
        act_bytes: GB,
        prefill_flops_per_token: 16e9,
        decode_flops_per_token: 16e9,
        kv_bytes_per_token: 32 * 1024,
    }
}

/// The three-phase trace: serial template warmup, one pool-sized squeeze,
/// then the zipf-shared bulk arriving faster than it drains.
fn workload(n: usize, seed: u64) -> Vec<Request> {
    let mut wl = WorkloadConfig {
        prompt_min: 8192, // private suffix; generate() prepends the prefix
        prompt_max: 8192,
        gen_min: 2048,
        gen_max: 2048,
        prefix_share_ratio: 1.0,
        prefix_templates: 4,
        prefix_tokens: 8192,
        ..WorkloadConfig::long_context(n, seed)
    }
    .generate();

    let mut seen = std::collections::HashSet::new();
    let (mut warm, mut bulk) = (Vec::new(), Vec::new());
    for r in wl.drain(..) {
        let head = *r.block_hashes.first().expect("share ratio 1.0 stamps every request");
        if seen.insert(head) {
            warm.push(r);
        } else {
            bulk.push(r);
        }
    }
    // Serial warmup: each template prefills and retires cold before the
    // next arrives (a request runs ~7 simulated seconds).
    for (i, r) in warm.iter_mut().enumerate() {
        r.arrival_us = i as f64 * 15e6;
    }
    // Bulk load: 0.2 s spacing against ~6 s of service saturates the
    // batch and keeps it saturated.
    for (j, r) in bulk.iter_mut().enumerate() {
        r.arrival_us = 80e6 + j as f64 * 0.2e6;
    }
    let squeeze = Request {
        id: 1_000_000,
        arrival_us: 70e6,
        prompt_tokens: SQUEEZE_TOKENS,
        gen_tokens: 1,
        block_hashes: Vec::new(),
    };
    let mut trace = warm;
    trace.push(squeeze);
    trace.extend(bulk);
    trace
}

fn run(depth: usize, wl: Vec<Request>) -> ServingReport {
    let mut hw = hw();
    let topo = match depth {
        2 => None,
        3 => Some(TierTopology::three_tier(&hw)),
        5 => Some(TierTopology::five_tier(&hw)),
        d => unreachable!("no {d}-tier row"),
    };
    if let Some(topo) = topo {
        hw = hw.with_tiers(topo);
    }
    let cfg = EngineConfig {
        max_batch: 4,
        // Both rows price pool exhaustion in peak HBM instead of
        // preemptions, so peak_device_bytes is the apples-to-apples
        // pressure gauge.
        device_spill: true,
        ..EngineConfig::hierarchical(hw, model())
    };
    SimServingEngine::new(cfg).run(wl).expect("serving run")
}

fn main() {
    let tiny = std::env::args().any(|a| a == "tiny");
    let n_requests = if tiny { 12 } else { 28 };

    let wl = workload(n_requests, 43);
    let total = wl.len() as u64;

    let rows = [
        ("2-tier", run(2, wl.clone())),
        ("3-tier", run(3, wl.clone())),
        ("5-tier", run(5, wl)),
    ];

    let mut t = Table::new(
        format!(
            "tier hierarchy ablation ({total} requests, 4 x 256 MiB templates, \
             {} MiB pool)",
            POOL_CHUNKS * BLOCK / MB
        ),
        &[
            "config",
            "tok/s",
            "p99 e2e ms",
            "peak dev GB",
            "cold fetch MB",
            "hit blocks",
            "preempt",
            "rejected",
        ],
    );
    for (name, r) in &rows {
        t.row(&[
            (*name).into(),
            f(r.throughput_tok_per_s, 0),
            f(r.e2e_latency_us.p99 / 1e3, 1),
            f(r.peak_device_bytes as f64 / 1e9, 3),
            f(r.cold_fetch_bytes as f64 / 1e6, 1),
            r.prefix_hit_blocks.to_string(),
            r.preempted_events.to_string(),
            r.rejected_requests.to_string(),
        ]);
    }
    t.print();

    let (flat, deep, five) = (&rows[0].1, &rows[1].1, &rows[2].1);
    for (name, r) in &rows {
        assert_eq!(r.rejected_requests, 0, "{name}: rejected requests");
        assert_eq!(
            r.e2e_latency_us.n as u64, total,
            "{name}: completed {} of {total} requests",
            r.e2e_latency_us.n
        );
    }
    assert_eq!(flat.cold_fetch_bytes, 0, "2-tier stack has no cold tier to fetch from");
    assert!(deep.cold_fetch_bytes > 0, "3-tier run never touched a demoted block");
    assert!(
        deep.peak_device_bytes < flat.peak_device_bytes,
        "3-tier peak HBM {} must be strictly below 2-tier {}",
        deep.peak_device_bytes,
        flat.peak_device_bytes
    );
    assert!(
        deep.prefix_hit_blocks > flat.prefix_hit_blocks,
        "demotion must preserve more prefix hits ({} vs {}) than eviction",
        deep.prefix_hit_blocks,
        flat.prefix_hit_blocks
    );
    assert!(
        deep.e2e_latency_us.p99 <= 1.5 * flat.e2e_latency_us.p99,
        "3-tier p99 {} blew the 1.5x tail budget over 2-tier {}",
        deep.e2e_latency_us.p99,
        flat.e2e_latency_us.p99
    );
    // The 5-tier stack adds CXL and SSD below DRAM. The squeezed
    // templates still demote no deeper than DRAM (its capacity is never
    // the constraint here), so the deep-stack wins carry over — the
    // extra levels must not cost anything on a DRAM-resident trace.
    assert!(five.cold_fetch_bytes > 0, "5-tier run never touched a demoted block");
    assert!(
        five.peak_device_bytes < flat.peak_device_bytes,
        "5-tier peak HBM {} must be strictly below 2-tier {}",
        five.peak_device_bytes,
        flat.peak_device_bytes
    );
    assert!(
        five.prefix_hit_blocks > flat.prefix_hit_blocks,
        "5-tier demotion must preserve more prefix hits ({} vs {}) than eviction",
        five.prefix_hit_blocks,
        flat.prefix_hit_blocks
    );
    assert!(
        five.e2e_latency_us.p99 <= 1.5 * flat.e2e_latency_us.p99,
        "5-tier p99 {} blew the 1.5x tail budget over 2-tier {}",
        five.e2e_latency_us.p99,
        flat.e2e_latency_us.p99
    );

    // Machine-readable trajectory for CI (schema-checked, values tracked
    // as an artifact).
    let mut json = String::from("{\n  \"bench\": \"tier_hierarchy\",\n  \"rows\": [\n");
    for (i, (name, r)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"config\": \"{}\", \"throughput_tok_s\": {:.3}, \
             \"p99_e2e_us\": {:.3}, \"peak_device_bytes\": {}, \
             \"cold_fetch_bytes\": {}, \"prefix_hit_blocks\": {}, \
             \"kv_transfer_bytes\": {}, \"preempted_events\": {}, \
             \"rejected_requests\": {}}}{}\n",
            name,
            r.throughput_tok_per_s,
            r.e2e_latency_us.p99,
            r.peak_device_bytes,
            r.cold_fetch_bytes,
            r.prefix_hit_blocks,
            r.kv_transfer_bytes,
            r.preempted_events,
            r.rejected_requests,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ]\n}\n");
    let path = "BENCH_tier_hierarchy.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }

    println!(
        "\nboth rows run the identical trace with the identical pool: the only\n\
         difference is whether a cold prefix chain under pressure is evicted\n\
         (2-tier) or demoted to DRAM (3-tier). demotion keeps the pool free of\n\
         template bytes — admissions attach to the DRAM copies and pay a cold\n\
         fetch — so live pool demand stays under capacity and decode growth\n\
         never spills into HBM, while the 2-tier row re-prefills templates\n\
         into the pool, pins them live, and overflows through the spill valve."
    );
}
