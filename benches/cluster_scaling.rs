//! Cluster scaling — throughput and P99 e2e latency vs. replica count
//! under the same aggregate arrival rate, baseline vs. hierarchical,
//! showing where the shared device↔pool fabric saturates (the §7
//! multi-NPU setting: the pool is one resource, not N private ones).
//!
//! Second table: online least-loaded routing (live outstanding tokens +
//! completion feedback) vs. the static token-count partition on a bursty
//! arrival trace — the placement signal, not the capacity, is what
//! separates them.

use hyperoffload::serving::{
    ClusterConfig, EngineConfig, ModelCost, Request, SimCluster, WorkloadConfig,
};
use hyperoffload::sim::{HwConfig, GB};
use hyperoffload::util::rng::Rng;
use hyperoffload::util::table::{f, Table};

fn model() -> ModelCost {
    ModelCost {
        weights_bytes: 8 * GB,
        act_bytes: GB,
        prefill_flops_per_token: 16e9,
        decode_flops_per_token: 16e9,
        kv_bytes_per_token: 64 * 1024,
    }
}

fn hw() -> HwConfig {
    HwConfig::ascend910c_like().with_device_capacity(64 * GB)
}

fn main() {
    // One aggregate arrival stream: 64 chunky-prefill requests. The same
    // trace is fed to every cluster size, so per-replica load shrinks
    // with N while the shared fabric and pool stay fixed.
    let wl = WorkloadConfig {
        n_requests: 64,
        mean_interarrival_us: 20_000.0,
        prompt_min: 4_000,
        prompt_max: 12_000,
        gen_min: 16,
        gen_max: 96,
        seed: 42,
        prefix_share_ratio: 0.0,
        prefix_templates: 0,
        prefix_tokens: 0,
        prefix_block_tokens: 64,
        prefix_zipf_s: 0.0,
        burst_phases: 0,
        burst_factor: 1.0,
    }
    .generate();

    let mut t = Table::new(
        "cluster scaling under one SuperNode pool (64 requests, same trace)",
        &[
            "replicas",
            "policy",
            "tok/s",
            "p99 e2e ms",
            "exposed xfer ms",
            "fabric stall ms",
            "pool peak GB",
            "preempted",
            "rejected",
        ],
    );
    for &n in &[1usize, 2, 4, 8] {
        for (name, engine) in [
            ("baseline", EngineConfig::baseline(hw(), model())),
            ("hierarchical", EngineConfig::hierarchical(hw(), model())),
        ] {
            let r = SimCluster::new(ClusterConfig::new(engine, n))
                .run(wl.clone())
                .unwrap();
            t.row(&[
                n.to_string(),
                name.into(),
                f(r.throughput_tok_per_s, 0),
                f(r.e2e_latency_us.p99 / 1e3, 1),
                f(r.exposed_transfer_us / 1e3, 1),
                f(r.fabric_stall_us / 1e3, 1),
                f(r.pool_peak_bytes as f64 / 1e9, 2),
                r.preempted_events.to_string(),
                r.rejected.to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "\nthe hierarchical rows saturate the fabric as N grows: per-link\n\
         transfers degrade to aggregate/k, so exposed transfer time and\n\
         fabric stall climb even though per-replica load shrinks."
    );

    // Bursty trace: 6 bursts of 8 requests with heavy-tailed gen lengths.
    // Static partition balances token totals (prompt+gen), which are
    // dominated by prompts — but wall time is dominated by decode steps,
    // so cumulative token counters are a misleading load signal.
    let mut rng = Rng::new(7);
    let mut bursty: Vec<Request> = Vec::new();
    for burst in 0..6u64 {
        let t0 = burst as f64 * 2_000_000.0;
        for i in 0..8u64 {
            let heavy = rng.next_f64() < 0.25;
            bursty.push(Request {
                id: burst * 8 + i,
                arrival_us: t0 + rng.f64_range(0.0, 50_000.0),
                prompt_tokens: rng.usize(512, 8_192),
                gen_tokens: if heavy { rng.usize(400, 800) } else { rng.usize(8, 64) },
                block_hashes: vec![],
            });
        }
    }

    let mut t = Table::new(
        "online routing vs static partition (4 replicas, bursty trace, max_batch 2)",
        &["dispatch", "policy", "p50 e2e ms", "p99 e2e ms", "tok/s"],
    );
    let engine = EngineConfig { max_batch: 2, ..EngineConfig::hierarchical(hw(), model()) };
    for (name, static_partition) in [("online least-loaded", false), ("static partition", true)] {
        let r = SimCluster::new(
            ClusterConfig::new(engine.clone(), 4).with_static_partition(static_partition),
        )
        .run(bursty.clone())
        .unwrap();
        t.row(&[
            name.into(),
            "hierarchical".into(),
            f(r.e2e_latency_us.p50 / 1e3, 1),
            f(r.e2e_latency_us.p99 / 1e3, 1),
            f(r.throughput_tok_per_s, 0),
        ]);
    }
    t.print();
    println!(
        "\nonline dispatch reads live outstanding work and completion\n\
         feedback, so a drained replica takes the next burst; the static\n\
         partition keeps stacking by stale cumulative token counts."
    );
}
