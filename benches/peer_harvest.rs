//! Peer-to-peer HBM harvesting — idle-replica HBM as a revocable middle
//! tier between local HBM and the shared SuperNode pool (ISSUE 10).
//!
//! Four replicas serve one skewed, bursty open-loop trace
//! ([`WorkloadConfig::skewed_bursty`]): zipf-reused shared templates plus
//! arrivals alternating calm and burst phases. During the calm phases
//! most replicas drain idle and open themselves as *lenders*; the replica
//! still decoding borrows their spare HBM, so its private KV blocks live
//! at `Tier::Peer(lender)` and every working-set fetch rides the
//! device↔device edge instead of the 33.6 GB/s pool link. A burst then
//! loads the lenders past their revocation threshold: every live lease is
//! revoked and the borrowed blocks demote to the pool — reserve-first,
//! exactly once, never dropped (a full pool parks the block at the peer
//! for a later sweep).
//!
//! Two rows run the identical trace on the identical hardware:
//!
//! * **pool-only** — harvesting off; all KV traffic funnels through the
//!   shared pool fabric.
//! * **harvest** — idle HBM lent and revoked as the phases alternate.
//!
//! Asserted acceptance criteria (ISSUE 10): the harvest row finishes with
//! *strictly* higher throughput AND *strictly* lower P99 e2e latency at
//! equal-or-lower peak pool occupancy, its revocation count is nonzero
//! (the protocol's hard path ran), and a zero-spare harvest config — all
//! the wiring engaged, no bytes to lend — reproduces the pool-only run
//! bit for bit.
//!
//! Besides the table the run emits `BENCH_peer_harvest.json` for CI
//! (schema-checked against the committed snapshot at
//! `benches/snapshots/BENCH_peer_harvest.json`). Pass `tiny` as the first
//! argument for the CI-sized workload.

use hyperoffload::serving::{
    ClusterConfig, ClusterReport, EngineConfig, ModelCost, PeerHarvestConfig, SimCluster,
    WorkloadConfig,
};
use hyperoffload::sim::{HwConfig, GB};
use hyperoffload::util::table::{f, Table};

const N_REPLICAS: usize = 4;

/// Ascend-910C-like replicas joined by a 392 GB/s device↔device edge
/// (the SuperNode intra-node fabric), with the shared pool sized so a
/// burst's live KV brushes capacity — pool pressure is what makes the
/// harvested middle tier worth having.
fn hw() -> HwConfig {
    let mut hw = HwConfig::ascend910c_like()
        .with_device_capacity(64 * GB)
        .with_peer_link(392.0, 5.0);
    hw.remote_capacity = 3 * GB;
    hw
}

fn model() -> ModelCost {
    ModelCost {
        weights_bytes: 8 * GB,
        act_bytes: GB,
        prefill_flops_per_token: 16e9,
        decode_flops_per_token: 16e9,
        kv_bytes_per_token: 64 * 1024,
    }
}

/// Lender policy: a replica lends while nearly idle (≤ 512 outstanding
/// tokens — less than one typical request), stops matching new borrows
/// above that, and revokes outright once a burst piles more than two
/// requests' worth of work on it.
fn harvest_policy() -> PeerHarvestConfig {
    PeerHarvestConfig {
        spare_bytes: 8 * GB,
        lend_below_tokens: 512,
        revoke_above_tokens: 4096,
    }
}

fn run(harvest: Option<PeerHarvestConfig>, wl: &[hyperoffload::serving::Request]) -> ClusterReport {
    // Generous preemption retries: pool exhaustion under the burst may
    // preempt, but the identical trace must complete in every row.
    let engine = EngineConfig {
        max_preemptions: 64,
        ..EngineConfig::hierarchical(hw(), model())
    };
    let mut cfg = ClusterConfig::new(engine, N_REPLICAS);
    if let Some(ph) = harvest {
        cfg = cfg.with_peer_harvest(ph);
    }
    SimCluster::new(cfg).run(wl.to_vec()).expect("cluster run")
}

fn main() {
    let tiny = std::env::args().any(|a| a == "tiny");
    let (n_requests, phases) = if tiny { (48, 2) } else { (192, 3) };

    // Calm gaps (400 ms mean across the cluster) let replicas drain idle
    // between requests; burst phases compress the gaps 12x, stacking
    // several requests' worth of work on every replica at once.
    let wl = WorkloadConfig::skewed_bursty(n_requests, 400_000.0, phases, 12.0, 29).generate();
    let total = wl.len() as u64;

    let rows = [
        ("pool-only", run(None, &wl)),
        ("harvest", run(Some(harvest_policy()), &wl)),
    ];

    let mut t = Table::new(
        format!(
            "peer-HBM harvesting ({total} requests, {N_REPLICAS} replicas, \
             {phases} burst phases, 3 GiB pool)"
        ),
        &[
            "config",
            "tok/s",
            "p99 e2e ms",
            "pool peak GB",
            "peer fetch MB",
            "revoked MB",
            "revocations",
            "preempt",
            "rejected",
        ],
    );
    for (name, r) in &rows {
        t.row(&[
            (*name).into(),
            f(r.throughput_tok_per_s, 0),
            f(r.e2e_latency_us.p99 / 1e3, 1),
            f(r.pool_peak_bytes as f64 / 1e9, 3),
            f(r.peer_fetch_bytes as f64 / 1e6, 1),
            f(r.peer_revoked_bytes as f64 / 1e6, 1),
            r.peer_revocations.to_string(),
            r.preempted_events.to_string(),
            r.rejected.to_string(),
        ]);
    }
    t.print();

    let (pool, peer) = (&rows[0].1, &rows[1].1);
    for (name, r) in &rows {
        assert_eq!(r.rejected, 0, "{name}: rejected requests");
        assert_eq!(r.completed, total, "{name}: completed {} of {total}", r.completed);
        assert!(
            r.pool_peak_bytes <= r.pool_capacity_bytes,
            "{name}: pool over capacity"
        );
    }
    assert_eq!(pool.peer_fetch_bytes, 0, "pool-only row must never touch a peer");
    assert_eq!(pool.peer_revocations, 0);
    assert!(peer.borrowed_bytes_peak > 0, "calm phases must open lenders");
    assert!(peer.peer_fetch_bytes > 0, "decode must fetch over the peer edge");
    assert!(
        peer.peer_revocations > 0,
        "bursts must revoke live leases — the protocol's hard path never ran"
    );
    assert!(peer.peer_revoked_bytes > 0, "revocation must demote bytes to the pool");
    assert!(
        peer.throughput_tok_per_s > pool.throughput_tok_per_s,
        "harvest throughput {} must strictly beat pool-only {}",
        peer.throughput_tok_per_s,
        pool.throughput_tok_per_s
    );
    assert!(
        peer.e2e_latency_us.p99 < pool.e2e_latency_us.p99,
        "harvest p99 {} must strictly beat pool-only {}",
        peer.e2e_latency_us.p99,
        pool.e2e_latency_us.p99
    );
    assert!(
        peer.pool_peak_bytes <= pool.pool_peak_bytes,
        "harvest must not raise peak pool occupancy ({} > {})",
        peer.pool_peak_bytes,
        pool.pool_peak_bytes
    );

    // A zero-spare harvest is the protocol's fixpoint: lease registered,
    // broker running, router consulted — and no byte can ever match, so
    // the run must reproduce the pool-only row bit for bit.
    let off = run(Some(PeerHarvestConfig::default()), &wl);
    assert_eq!(off.borrowed_bytes_peak, 0);
    assert_eq!(off.peer_fetch_bytes, 0);
    assert_eq!(off.peer_revocations, 0);
    assert_eq!(off.total_time_us, pool.total_time_us, "zero-spare must be a fixpoint");
    assert_eq!(off.kv_transfer_bytes, pool.kv_transfer_bytes);
    assert_eq!(off.exposed_transfer_us, pool.exposed_transfer_us);
    assert_eq!(off.peak_device_bytes, pool.peak_device_bytes);
    assert_eq!(off.throughput_tok_per_s, pool.throughput_tok_per_s);

    // Machine-readable trajectory for CI (schema-checked, values tracked
    // as an artifact).
    let mut json = String::from("{\n  \"bench\": \"peer_harvest\",\n  \"rows\": [\n");
    for (i, (name, r)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"config\": \"{}\", \"throughput_tok_s\": {:.3}, \
             \"p99_e2e_us\": {:.3}, \"pool_peak_bytes\": {}, \
             \"peer_fetch_bytes\": {}, \"peer_store_bytes\": {}, \
             \"borrowed_bytes_peak\": {}, \"peer_revocations\": {}, \
             \"peer_revoked_bytes\": {}, \"preempted_events\": {}, \
             \"rejected_requests\": {}}}{}\n",
            name,
            r.throughput_tok_per_s,
            r.e2e_latency_us.p99,
            r.pool_peak_bytes,
            r.peer_fetch_bytes,
            r.peer_store_bytes,
            r.borrowed_bytes_peak,
            r.peer_revocations,
            r.peer_revoked_bytes,
            r.preempted_events,
            r.rejected,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ]\n}\n");
    let path = "BENCH_peer_harvest.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }

    println!(
        "\nboth rows serve the identical skewed/bursty trace: the only\n\
         difference is whether a replica that drains idle during a calm\n\
         phase lends its spare HBM. borrowed KV rides the 392 GB/s\n\
         device-device edge instead of the 33.6 GB/s pool link and pays\n\
         no pool capacity, so calm-phase decode runs faster and the pool\n\
         peak stays at or below the pool-only row; when a burst loads a\n\
         lender, its leases revoke and every borrowed block demotes into\n\
         the pool exactly once — throughput and tail latency improve\n\
         without ever dropping a byte."
    );
}
