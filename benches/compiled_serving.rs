//! Compiled serving path: analytic oracle vs per-step compiled KV
//! transfer graphs, with and without SLO throttling — plus the
//! round-trip chunking ablation on the compile side.
//!
//! Three serving rows over the same steady-decode workload:
//! * `analytic-oracle` — the retired backlog arithmetic
//!   (`EngineConfig::analytic_oracle`), kept as the conservation oracle;
//! * `compiled` — every step lowered and compiled through the `Compiler`
//!   session (`ExecOrder` → `SloThrottle` → elide);
//! * `compiled+slo-throttle` — the same with a per-decode-step SLO, so
//!   the throttle's spill rewrite shapes writebacks.
//!
//! A fourth section chunks a ≥128 MB Store/Prefetch round trip through
//! `SloThrottle` (partial-tensor residency) and reports peak/byte·time vs
//! the unsplit schedule.
//!
//! Besides the human-readable table the run emits
//! `BENCH_compiled_serving.json` — throughput, P99 decode step, peak
//! device bytes, deferred bytes, the compile-cache hit rate and the
//! step-compile latency (total + worst single compile, miss path only)
//! per configuration, plus the TransferSan analyze latency on the
//! round-trip schedules — so CI can track the perf trajectory and assert the
//! steady-state hit rate stays ≥ 90%. Pass `tiny` as the first argument
//! for the CI-sized workload. A representative snapshot is committed at
//! `benches/snapshots/BENCH_compiled_serving.json`.

use hyperoffload::analysis::analyze;
use hyperoffload::graph::{Graph, GraphBuilder, OpId, Reach, TrackedSet};
use hyperoffload::kvcache::NsaConfig;
use hyperoffload::passes::{Compiler, SloThrottle};
use hyperoffload::serving::{EngineConfig, ModelCost, ServingReport, SimServingEngine};
use hyperoffload::sim::{simulate, HwConfig, GB, MB};
use hyperoffload::util::table::{f, Table};

fn hw() -> HwConfig {
    HwConfig::ascend910c_like().with_device_capacity(64 * GB)
}

/// Writeback-heavy serving point: small weights (little compute to hide
/// under) and 16 MiB KV blocks, so the per-step tail-block persist is what
/// the decode SLO has to shape.
fn model() -> ModelCost {
    ModelCost {
        weights_bytes: 64 * MB,
        act_bytes: GB,
        prefill_flops_per_token: 16e9,
        decode_flops_per_token: 16e9,
        kv_bytes_per_token: 64 * 1024,
    }
}

fn cfg_base() -> EngineConfig {
    EngineConfig {
        nsa: NsaConfig { block_tokens: 256, ..Default::default() },
        ..EngineConfig::hierarchical(hw(), model())
    }
}

struct Row {
    name: &'static str,
    report: ServingReport,
}

fn main() {
    let tiny = std::env::args().any(|a| a == "tiny");
    let (n_seqs, gen_tokens): (u64, usize) = if tiny { (2, 150) } else { (6, 800) };

    // Steady decode: long generations over modest prompts, so the run is
    // dominated by repeating decode-step shapes.
    let wl: Vec<hyperoffload::serving::Request> = (0..n_seqs)
        .map(|i| hyperoffload::serving::Request {
            id: i,
            arrival_us: 0.0,
            prompt_tokens: 4096,
            gen_tokens,
            block_hashes: vec![],
        })
        .collect();

    let slo_us = 3_000.0; // below the unshaped step, above the tiny-mode floor
    let configs: Vec<(&'static str, EngineConfig)> = vec![
        (
            "analytic-oracle",
            EngineConfig {
                decode_slo_us: Some(slo_us),
                analytic_oracle: true,
                ..cfg_base()
            },
        ),
        ("compiled", cfg_base()),
        (
            "compiled+slo-throttle",
            EngineConfig { decode_slo_us: Some(slo_us), ..cfg_base() },
        ),
    ];

    let mut rows: Vec<Row> = Vec::new();
    for (name, cfg) in configs {
        let report = SimServingEngine::new(cfg).run(wl.clone()).expect(name);
        rows.push(Row { name, report });
    }

    let mut t = Table::new(
        format!("compiled serving path ({n_seqs} seqs x {gen_tokens} decode steps)"),
        &[
            "config",
            "tok/s",
            "p99 decode ms/tok",
            "max step ms",
            "peak GB",
            "deferred MB",
            "cache hit %",
            "compile ms",
        ],
    );
    for r in &rows {
        t.row(&[
            r.name.into(),
            f(r.report.throughput_tok_per_s, 0),
            f(r.report.decode_per_token_us.p99 / 1e3, 3),
            f(r.report.decode_step_us_max / 1e3, 3),
            f(r.report.peak_device_bytes as f64 / 1e9, 2),
            f(r.report.slo_deferred_bytes as f64 / 1e6, 1),
            f(r.report.compile_cache_hit_rate() * 100.0, 1),
            f(r.report.compile_us_total / 1e3, 1),
        ]);
    }
    t.print();

    // Conservation cross-check against the oracle (the P12 property on
    // the bench workload): identical KV bytes moved.
    let oracle_bytes = rows[0].report.kv_transfer_bytes;
    for r in &rows[1..] {
        assert_eq!(
            r.report.kv_transfer_bytes, oracle_bytes,
            "{}: byte totals diverged from the analytic oracle",
            r.name
        );
    }
    // Steady-state decode must amortise compilation to a hash lookup.
    for r in &rows[1..] {
        let rate = r.report.compile_cache_hit_rate();
        assert!(rate >= 0.9, "{}: compile-cache hit rate {rate:.3} < 0.90", r.name);
    }

    // ---- round-trip chunking ablation (compile side) --------------------
    // A 256 MB activation's Store/Prefetch round trip, unsplit vs chunked
    // by the throttle into partial-tensor transfers.
    let build = || {
        let mut b = GraphBuilder::new();
        let act = b.tensor("act", 256 << 20, hyperoffload::graph::Tier::Device);
        let sink = b.tensor("sink", 0, hyperoffload::graph::Tier::Device);
        b.compute("fwd", 1e9, 0, vec![], vec![act]);
        let mut prev = None;
        for i in 0..8 {
            let t = b.tensor(&format!("m{i}"), 0, hyperoffload::graph::Tier::Device);
            let inputs = prev.map(|p| vec![p]).unwrap_or_default();
            let o = b.compute(&format!("mid{i}"), 4e12, 0, inputs, vec![t]);
            if i == 0 {
                b.dep(o, 0);
            }
            prev = Some(t);
        }
        b.compute("bwd", 1e9, 0, vec![act, prev.unwrap()], vec![sink]);
        b.build()
    };
    let chw = hw().with_pool_bandwidth(5.0);
    let mut base = build();
    let rb = Compiler::new(chw.clone()).compile(&mut base).expect("base compile");
    let sb = simulate(&base, &rb.order, &chw);
    let slo = sb.makespan_us * 1.1;
    let throttle = |split_min: u64| SloThrottle {
        split_min_bytes: split_min,
        defer_prefetches: false,
        ..Default::default()
    };
    let mut unsplit = build();
    let ru = Compiler::new(chw.clone())
        .slo_us(slo)
        .pass(throttle(0))
        .verify(true)
        .compile(&mut unsplit)
        .expect("unsplit compile");
    let su = simulate(&unsplit, &ru.order, &chw);
    let mut split = build();
    let rs = Compiler::new(chw.clone())
        .slo_us(slo)
        .pass(throttle(64 << 20))
        .verify(true)
        .compile(&mut split)
        .expect("split compile");
    let ss = simulate(&split, &rs.order, &chw);

    // TransferSan latency on the compiled schedules: the same cache-op
    // reachability + lint walk the serving `StepCompiler` runs on every
    // cache-miss step, timed here so the snapshot tracks its cost next
    // to the compile it audits.
    let sanitize_us = |g: &Graph, order: &[OpId]| {
        let t0 = std::time::Instant::now();
        let anc = Reach::ancestors(g, order, TrackedSet::CacheOps);
        let r = analyze(g, order, &anc, &chw);
        std::hint::black_box(r.findings.len());
        t0.elapsed().as_secs_f64() * 1e6
    };
    let san_u = sanitize_us(&unsplit, &ru.order);
    let san_s = sanitize_us(&split, &rs.order);

    let mut t2 = Table::new(
        "round-trip chunking (256 MB activation, 5 GB/s link)",
        &["schedule", "chunked transfers", "makespan ms", "peak GB", "byte-time GB*s", "san us"],
    );
    for (name, chunked, s, san) in
        [("unsplit", ru.chunked, &su, san_u), ("chunked", rs.chunked, &ss, san_s)]
    {
        t2.row(&[
            name.into(),
            chunked.to_string(),
            f(s.makespan_us / 1e3, 2),
            f(s.peak_device_bytes as f64 / 1e9, 2),
            f(s.residency_byte_time() / 1e9 / 1e6, 3),
            f(san, 1),
        ]);
    }
    t2.print();
    assert!(
        ss.peak_device_bytes <= su.peak_device_bytes,
        "chunking must not raise peak residency"
    );

    // Machine-readable trajectory for CI.
    let mut json = String::from("{\n  \"bench\": \"compiled_serving\",\n  \"rows\": [\n");
    for r in rows.iter() {
        json.push_str(&format!(
            "    {{\"config\": \"{}\", \"throughput_tok_s\": {:.3}, \
             \"p99_decode_us_per_tok\": {:.3}, \"decode_step_us_max\": {:.3}, \
             \"peak_device_bytes\": {}, \"kv_transfer_bytes\": {}, \
             \"slo_deferred_bytes\": {}, \"compile_cache_hit_rate\": {:.4}, \
             \"compile_us_total\": {:.1}, \"compile_us_max\": {:.1}}}{}\n",
            r.name,
            r.report.throughput_tok_per_s,
            r.report.decode_per_token_us.p99,
            r.report.decode_step_us_max,
            r.report.peak_device_bytes,
            r.report.kv_transfer_bytes,
            r.report.slo_deferred_bytes,
            r.report.compile_cache_hit_rate(),
            r.report.compile_us_total,
            r.report.compile_us_max,
            ",",
        ));
    }
    json.push_str(&format!(
        "    {{\"config\": \"roundtrip-unsplit\", \"makespan_us\": {:.3}, \
         \"peak_device_bytes\": {}, \"chunked\": {}, \"sanitize_us\": {:.1}}},\n    \
         {{\"config\": \"roundtrip-chunked\", \"makespan_us\": {:.3}, \
         \"peak_device_bytes\": {}, \"chunked\": {}, \"sanitize_us\": {:.1}}}\n",
        su.makespan_us, su.peak_device_bytes, ru.chunked, san_u, ss.makespan_us,
        ss.peak_device_bytes, rs.chunked, san_s,
    ));
    json.push_str("  ]\n}\n");
    let path = "BENCH_compiled_serving.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }

    println!(
        "\nthe serving engine no longer estimates what the compiler would do:\n\
         each step's KV traffic is lowered, compiled (ExecOrder -> SloThrottle\n\
         -> elide) and run, with steady-state decode amortised by the\n\
         shape-keyed compile cache; the SLO row shows the throttle spilling\n\
         writebacks, and the chunking section shows a 256 MB round trip\n\
         split into partial-tensor transfers without raising the peak."
    );
}
