//! Table 2 — DeepSeek-V3 training baseline configuration.
//!
//! Paper row: 2/2/2/4, batch 1, GBS 16, recompute disabled -> ~2500 ms.
//! The preset is parameter-scaled to single-SuperNode-slice feasibility
//! (DESIGN.md §2); the row reports the same breakdown columns.

use hyperoffload::sim::HwConfig;
use hyperoffload::training::{baseline_step, ModelPreset, ParallelCfg};
use hyperoffload::util::table::{f, Table};

fn main() {
    let hw = HwConfig::ascend910c_like();
    let m = ModelPreset::deepseek_v3_like();
    let cfg = ParallelCfg::dsv3_baseline();
    let s = baseline_step(&m, &cfg, &hw);

    let mut t = Table::new(
        "Table 2 — DeepSeek-V3 baseline configuration",
        &["DP/TP/PP/EP", "batch", "GBS", "recomp", "compute ms", "comm ms",
          "stall ms", "total ms", "demand GB", "paper"],
    );
    t.row(&[
        format!("{}/{}/{}/{}", cfg.dp, cfg.tp, cfg.pp, cfg.ep),
        cfg.micro_batch.to_string(),
        cfg.gbs.to_string(),
        if cfg.recompute { "On" } else { "Disabled" }.into(),
        f(s.compute_ms, 0),
        f(s.comm_ms, 0),
        f(s.stall_ms, 0),
        f(s.total_ms, 0),
        f(s.demand_bytes / 1e9, 1),
        "2500 ms".into(),
    ]);
    t.print();
    println!(
        "\nMoE sanity: active params {:.1}B of {:.0}B total per token ({:.1}%).",
        m.active_params_per_layer() * m.n_layers as f64 / 1e9,
        m.params / 1e9,
        m.active_params_per_layer() * m.n_layers as f64 / m.params * 100.0
    );
}
