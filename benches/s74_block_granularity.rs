//! §7.4 — sensitivity to sparse block granularity: decode-stage CPU and
//! copy overhead vs block size under hierarchical memory.
//!
//! Paper: "when the size of sparse blocks increases significantly, CPU
//! computation and memory copy overheads during the decode stage rise
//! noticeably" — performance is tied to sparse-structure granularity.

use hyperoffload::kvcache::NsaConfig;
use hyperoffload::serving::{EngineConfig, ModelCost, SimServingEngine, WorkloadConfig};
use hyperoffload::sim::HwConfig;
use hyperoffload::util::table::{f, Table};

fn main() {
    let model = ModelCost::dsv3_nsa_like();
    let mut hw = HwConfig::ascend910c_like();
    hw.device_capacity = 64_000_000_000;

    let wl = WorkloadConfig::short_sequence(16, 7).generate();
    let base = SimServingEngine::new(EngineConfig::baseline(hw.clone(), model.clone()))
        .run(wl.clone())
        .unwrap();

    let mut t = Table::new(
        "§7.4 — decode overhead vs sparse block granularity (hierarchical)",
        &["block tokens", "block MB", "decode s/token", "vs baseline", "KV moved GB/req"],
    );
    t.row(&[
        "baseline (device)".into(),
        "-".into(),
        f(base.decode_per_token_us.mean / 1e6, 4),
        "1.00x".into(),
        "0.0".into(),
    ]);
    for block_tokens in [16usize, 32, 64, 128, 256, 512] {
        let nsa = NsaConfig { block_tokens, ..Default::default() };
        let block_mb = nsa.block_bytes(model.kv_bytes_per_token) as f64 / 1e6;
        let hier = SimServingEngine::new(EngineConfig {
            nsa,
            ..EngineConfig::hierarchical(hw.clone(), model.clone())
        })
        .run(wl.clone())
        .unwrap();
        t.row(&[
            block_tokens.to_string(),
            f(block_mb, 1),
            f(hier.decode_per_token_us.mean / 1e6, 4),
            format!("{:.2}x", hier.decode_per_token_us.mean / base.decode_per_token_us.mean),
            f(hier.kv_transfer_bytes as f64 / 1e9 / 16.0, 2),
        ]);
    }
    t.print();
    println!(
        "\nexpected shape: decode overhead grows with block size (CPU block\n\
         processing + copy volume scale with granularity)."
    );
}
