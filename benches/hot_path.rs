//! Hot-path micro-benchmarks for the components that dominate compile and
//! simulation wall-clock. Run with `cargo run --release --bin hot_path`
//! and compare the printed table across commits when touching any of
//! these paths.
//!
//!   1. Algorithm 1 refinement on large graphs (positions x window scan),
//!      up to production scale (20k ops), plus an A/B of the full session
//!      pipeline (insert + refine + decision passes) with the incremental
//!      AnalysisCache and windowed re-simulation on (the default) vs off
//!      (the pre-incremental full-recompute path)
//!   2. simulate() list-scheduling throughput
//!   3. DeviceAllocator alloc/free churn
//!   4. serving engine decode iterations
//!   5. PJRT decode step (real execution), if artifacts exist

use std::time::Instant;

use hyperoffload::analysis::{analyze, to_diagnostics, LintConfig};
use hyperoffload::graph::{GraphBuilder, Reach, TrackedSet};
use hyperoffload::passes::{
    prefetch_insert, refine, Compiler, ExecOrderConfig, OffloadPolicy, RecomputeVsOffload,
    Severity, SloThrottle,
};
use hyperoffload::memory::DeviceAllocator;
use hyperoffload::serving::{EngineConfig, ModelCost, SimServingEngine, WorkloadConfig};
use hyperoffload::sim::{simulate, HwConfig, MB};
use hyperoffload::util::rng::Rng;
use hyperoffload::util::table::{f, Table};

fn time_it<F: FnMut()>(reps: usize, mut body: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        body();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    let hw = HwConfig::ascend910c_like();
    let mut t = Table::new("hot-path timings", &["path", "size", "time/op", "derived"]);

    // 1. Algorithm 1 on a large chain, up to production graph scale.
    for n in [200usize, 800, 2000, 20_000] {
        let reps = if n >= 20_000 { 1 } else { 3 };
        let secs = time_it(reps, || {
            let (mut g, _) = GraphBuilder::chain_with_remote_weights(n, 4e12, MB, 64 * MB);
            let order0 = g.topo_order().unwrap();
            prefetch_insert::run(&mut g, &order0, &hw, &OffloadPolicy::default());
            let r = refine(&mut g, &hw, &ExecOrderConfig::default());
            std::hint::black_box(r.order.len());
        });
        t.row(&[
            "Algorithm 1 (insert+refine)".into(),
            format!("{n} ops"),
            format!("{:.1} ms", secs * 1e3),
            format!("{:.2} us/op", secs * 1e6 / n as f64),
        ]);
    }

    // 1b. Full session compile at production scale: incremental analyses
    // + windowed re-simulation (the shipped defaults) against the
    // pre-incremental path (version-keyed cache patching off, every
    // decision-pass speculation validated by a full re-refine +
    // re-simulate). Both arms run the same pipeline and produce the same
    // schedule; only the validation machinery differs.
    {
        let n = 20_000usize;
        let mut compile_secs = |fast: bool| {
            let (mut g, _) = GraphBuilder::chain_with_remote_weights(n, 4e12, MB, 64 * MB);
            let t0 = Instant::now();
            let report = Compiler::new(hw.clone())
                .policy(OffloadPolicy { min_bytes: 16 << 20, ..Default::default() })
                .incremental(fast)
                .slo_us(1e15)
                .pass(RecomputeVsOffload { windowed: fast, ..Default::default() })
                .pass(SloThrottle { windowed: fast, ..Default::default() })
                .compile(&mut g)
                .unwrap();
            std::hint::black_box(report.order.len());
            t0.elapsed().as_secs_f64()
        };
        let fast = compile_secs(true);
        let slow = compile_secs(false);
        t.row(&[
            "full compile, incremental+windowed".into(),
            format!("{n} ops"),
            format!("{:.1} ms", fast * 1e3),
            format!("{:.2}x vs full-recompute ({:.1} ms)", slow / fast, slow * 1e3),
        ]);
    }

    // 1c. TransferSan on the same production-scale compile: cache-op
    // reachability plus the full lint walk, timed against the pipeline
    // it audits. The analyzer must stay under 10% of compile time at
    // 20k ops — that bound is what lets `sanitize(true)` ride in the
    // default strict-verify CI job and on every serving step compile.
    {
        let n = 20_000usize;
        let (mut g, _) = GraphBuilder::chain_with_remote_weights(n, 4e12, MB, 64 * MB);
        let t0 = Instant::now();
        let report = Compiler::new(hw.clone())
            .policy(OffloadPolicy { min_bytes: 16 << 20, ..Default::default() })
            .slo_us(1e15)
            .pass(RecomputeVsOffload::default())
            .pass(SloThrottle::default())
            .compile(&mut g)
            .unwrap();
        let compile = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let anc = Reach::ancestors(&g, &report.order, TrackedSet::CacheOps);
        let a = analyze(&g, &report.order, &anc, &hw);
        let san = t1.elapsed().as_secs_f64();
        std::hint::black_box(a.findings.len());
        let diags = to_diagnostics(&a, &LintConfig::default());
        assert!(
            diags.iter().all(|d| d.severity != Severity::Error),
            "TransferSan flagged the compiled {n}-op graph: {:?}",
            diags.iter().find(|d| d.severity == Severity::Error)
        );
        assert!(
            san < compile * 0.10,
            "TransferSan {:.1} ms is >=10% of the {:.1} ms full-pipeline compile",
            san * 1e3,
            compile * 1e3
        );
        t.row(&[
            "TransferSan (reach+analyze)".into(),
            format!("{n} ops"),
            format!("{:.1} ms", san * 1e3),
            format!("{:.1}% of {:.0} ms compile", 100.0 * san / compile, compile * 1e3),
        ]);
    }

    // 2. Simulator throughput.
    for n in [500usize, 2000, 8000] {
        let g = GraphBuilder::linear_chain(n, 1e12, MB);
        let order = g.topo_order().unwrap();
        let secs = time_it(5, || {
            std::hint::black_box(simulate(&g, &order, &hw).makespan_us);
        });
        t.row(&[
            "simulate() list scheduling".into(),
            format!("{n} ops"),
            format!("{:.2} ms", secs * 1e3),
            format!("{:.0} ns/op", secs * 1e9 / n as f64),
        ]);
    }

    // 3. Allocator churn.
    {
        let secs = time_it(5, || {
            let mut a = DeviceAllocator::new(1 << 30);
            let mut rng = Rng::new(1);
            let mut live = Vec::new();
            for _ in 0..20_000 {
                if rng.next_f64() < 0.55 || live.is_empty() {
                    if let Ok((id, _)) = a.alloc(1 + rng.gen_range(0, 1 << 16)) {
                        live.push(id);
                    }
                } else {
                    let i = rng.usize(0, live.len());
                    let id = live.swap_remove(i);
                    a.free(id).unwrap();
                }
            }
            std::hint::black_box(a.used());
        });
        t.row(&[
            "DeviceAllocator churn".into(),
            "20k ops".into(),
            format!("{:.2} ms", secs * 1e3),
            format!("{:.0} ns/alloc", secs * 1e9 / 20_000.0),
        ]);
    }

    // 4. Serving engine decode iterations.
    {
        let model = ModelCost::dsv3_nsa_like();
        let wl = WorkloadConfig::short_sequence(16, 3).generate();
        let secs = time_it(3, || {
            let r = SimServingEngine::new(EngineConfig::hierarchical(hw.clone(), model.clone()))
                .run(wl.clone())
                .unwrap();
            std::hint::black_box(r.tokens_generated);
        });
        t.row(&[
            "serving engine (16 reqs)".into(),
            "sim".into(),
            format!("{:.1} ms", secs * 1e3),
            "".into(),
        ]);
    }

    // 5. Compile pipeline end-to-end on the training graph.
    {
        use hyperoffload::training::{build_step_graph, ModelPreset, ParallelCfg};
        let secs = time_it(3, || {
            let mut sg = build_step_graph(&ModelPreset::llama8b(), &ParallelCfg::llama_hier());
            let report = Compiler::new(hw.clone())
                .policy(OffloadPolicy { min_bytes: 16 << 20, ..Default::default() })
                .compile(&mut sg.graph)
                .unwrap();
            std::hint::black_box(simulate(&sg.graph, &report.order, &hw).makespan_us);
        });
        t.row(&[
            "training step compile+sim".into(),
            "llama8b".into(),
            format!("{:.1} ms", secs * 1e3),
            "".into(),
        ]);
    }

    // 6. Real PJRT decode step if artifacts are present (xla feature).
    #[cfg(feature = "xla")]
    {
    let dir = std::path::PathBuf::from("artifacts");
    if dir.join("meta.txt").exists() {
        use hyperoffload::runtime::ModelRuntime;
        let client = xla::PjRtClient::cpu().unwrap();
        let model = ModelRuntime::load(&client, &dir).unwrap();
        let tokens: Vec<i32> = vec![1; model.spec.batch * model.spec.prefill_len];
        let (logits, kc, vc) = model.run_prefill(&tokens).unwrap();
        let next = model.argmax_tokens(&logits);
        let p = model.spec.prefill_len as i32;
        let secs = time_it(20, || {
            let (l, _, _) = model.run_decode(&next, p, &kc, &vc).unwrap();
            std::hint::black_box(l[0]);
        });
        t.row(&[
            "PJRT decode step (real)".into(),
            format!("B={}", model.spec.batch),
            format!("{:.2} ms", secs * 1e3),
            format!("{:.0} tok/s", model.spec.batch as f64 / secs),
        ]);
    }
    }

    t.print();
}
