//! Fig. 3 + §3.1 motivation: serial vs runtime-driven prefetching vs
//! statically-orchestrated (graph-driven) execution on an 8-NPU-node
//! LLaMA-8B-like inference pass.
//!
//! Paper's measurement: baseline 5.5 s; runtime-driven prefetch 15 s
//! (2.7x slowdown: 9 s unhidden compute+comm, 6.7 s compaction/management).
//! We reproduce the ORDERING and the ~2-3x slowdown factor of the
//! runtime-driven path, and show graph-driven scheduling removing it.

use hyperoffload::graph::GraphBuilder;
use hyperoffload::passes::Compiler;
use hyperoffload::runtime_sched::{simulate_reactive, ReactiveConfig, ReactiveMode};
use hyperoffload::sim::{simulate, HwConfig, MB};
use hyperoffload::util::table::{f, Table};

fn main() {
    let hw = HwConfig::ascend910c_like();

    // LLaMA-8B-like forward: 32 layers, ~170 ms compute each at this
    // scale, each streaming a 500 MB weight+KV slice from the pool.
    let (graph, _) = GraphBuilder::chain_with_remote_weights(32, 55e12, 256 * MB, 500 * MB);

    let baseline = {
        // "Baseline execution" = weights resident, no pool traffic: pure
        // compute chain.
        let g = GraphBuilder::linear_chain(32, 55e12, 256 * MB);
        let order = g.topo_order().unwrap();
        simulate(&g, &order, &hw)
    };

    // Runtime-driven prefetching (the 2.7x configuration): fine-grained
    // firing with CPU control path on every transfer plus periodic
    // compaction/management stalls.
    // Calibrated to the paper's breakdown: §3.1 reports 6.7 s of the 15 s
    // spent in compaction/system management — ~210 ms per transfer here.
    let runtime = simulate_reactive(
        &graph,
        &ReactiveConfig {
            mode: ReactiveMode::Prefetch { lookahead: 1 },
            compaction_every: 1,
            compaction_us: 210_000.0,
        },
        &hw,
    );
    let serial = simulate_reactive(&graph, &ReactiveConfig::default(), &hw);

    let mut g = graph.clone();
    let report = Compiler::new(hw.clone()).compile(&mut g).expect("compile");
    let ours = simulate(&g, &report.order, &hw);

    let base_s = baseline.makespan_us / 1e6;
    let mut t = Table::new(
        "Fig.3 / §3.1 — execution strategies on the pool-streaming workload",
        &["strategy", "time s", "vs baseline", "exposed comm s", "bubbles s"],
    );
    for (name, r) in [
        ("baseline (resident)", &baseline),
        ("serial on-demand (3a)", &serial),
        ("runtime-driven prefetch (3b)", &runtime),
        ("HyperOffload static (3c)", &ours),
    ] {
        t.row(&[
            name.into(),
            f(r.makespan_us / 1e6, 2),
            format!("{:.2}x", r.makespan_us / 1e6 / base_s),
            f(r.exposed_comm_us / 1e6, 2),
            f((r.makespan_us - r.compute_busy_us - r.exposed_comm_us).max(0.0) / 1e6, 2),
        ]);
    }
    t.print();
    println!(
        "\npaper: runtime-driven = 2.7x baseline (5.5s -> 15s); ours: {:.2}x. \
         graph-driven restores {:.2}x.",
        runtime.makespan_us / baseline.makespan_us,
        ours.makespan_us / baseline.makespan_us
    );
}
