//! Table 1 — LLaMA-8B training baseline configurations.
//!
//! Paper rows:
//!   No.1  8/1/1  batch 2  GBS 16  recompute on   -> 8000 ms+ (defrag-bound)
//!   No.2  2/2/2  batch 1  GBS 16  recompute off  -> 5200 ms  (stable)
//!
//! The simulator reproduces the *shape*: No.1 pays recompute + memory
//! pressure stalls and is clearly slower and less stable than No.2.

use hyperoffload::sim::HwConfig;
use hyperoffload::training::{baseline_step, ModelPreset, ParallelCfg};
use hyperoffload::util::table::{f, Table};

fn main() {
    let hw = HwConfig::ascend910c_like();
    let m = ModelPreset::llama8b();

    let rows = [
        ("No.1", ParallelCfg::llama_no1(), "8000 ms+"),
        ("No.2", ParallelCfg::llama_no2(), "5200 ms"),
    ];

    let mut t = Table::new(
        "Table 1 — LLaMA-8B baseline configurations",
        &["config", "DP/TP/PP", "batch", "GBS", "recomp", "compute ms", "comm ms",
          "stall ms", "total ms", "demand GB", "paper"],
    );
    let mut totals = Vec::new();
    for (name, cfg, paper) in rows {
        let s = baseline_step(&m, &cfg, &hw);
        totals.push(s.total_ms);
        t.row(&[
            name.into(),
            format!("{}/{}/{}", cfg.dp, cfg.tp, cfg.pp),
            cfg.micro_batch.to_string(),
            cfg.gbs.to_string(),
            if cfg.recompute { "On" } else { "Off" }.into(),
            f(s.compute_ms + s.recompute_ms, 0),
            f(s.comm_ms, 0),
            f(s.stall_ms, 0),
            f(s.total_ms, 0),
            f(s.demand_bytes / 1e9, 1),
            paper.into(),
        ]);
    }
    t.print();
    println!(
        "\nshape check: No.1/No.2 = {:.2}x (paper: >=1.54x). No.1 is pressure+recompute bound.",
        totals[0] / totals[1]
    );
}
