//! Fig. 6(b) — DeepSeek-V3 end-to-end training step breakdown vs D2H
//! bandwidth, against the 2/2/2/4 baseline (Table 2).
//!
//! Paper: +2%–12.3% over the bandwidth range; higher compute density means
//! communication hides more easily than for LLaMA-8B.

use hyperoffload::sim::HwConfig;
use hyperoffload::training::{baseline_step, hierarchical_step, ModelPreset, ParallelCfg};
use hyperoffload::util::table::{f, Table};

fn main() {
    let hw0 = HwConfig::ascend910c_like();
    let m = ModelPreset::deepseek_v3_like();
    let base = baseline_step(&m, &ParallelCfg::dsv3_baseline(), &hw0);
    let hier_cfg = ParallelCfg::dsv3_hier();

    println!(
        "baseline (Table 2): {:.0} ms | hierarchical layout 8/1/1/4, batch 2, GBS 16",
        base.total_ms
    );

    let mut t = Table::new(
        "Fig.6(b) — DeepSeek-V3 step breakdown vs D2H bandwidth",
        &["D2H GB/s", "exposed D2H ms", "overlapped D2H ms", "compute+other ms",
          "total ms", "vs baseline", "peak GB"],
    );
    let mut gains = Vec::new();
    for bw in [20.0, 33.6, 40.0, 50.0, 60.0, 70.0] {
        let s = hierarchical_step(&m, &hier_cfg, &hw0.clone().with_pool_bandwidth(bw));
        let other = s.total_ms - s.exposed_d2h_ms - s.compute_ms;
        let gain = (base.total_ms - s.total_ms) / base.total_ms * 100.0;
        gains.push(gain);
        t.row(&[
            f(bw, 1),
            f(s.exposed_d2h_ms, 0),
            f(s.overlapped_d2h_ms, 0),
            f(s.compute_ms + other.max(0.0), 0),
            f(s.total_ms, 0),
            format!("{gain:+.1}%"),
            f(s.peak_bytes / 1e9, 1),
        ]);
    }
    t.print();
    println!(
        "\npaper shape: stable +2%..+12.3% gains across bandwidths (denser compute\n\
         hides the traffic earlier than LLaMA-8B)."
    );
}
