//! Table 5 — inference latency breakdown in short-sequence scenarios
//! (low memory pressure, coarse sparse-block setting).
//!
//! Paper: prefill -0.48% (parity), decode 0.117 s -> 0.146 s (-25.47%,
//! CPU-side sparse block processing), end-to-end 0.15% (negligible).

use hyperoffload::kvcache::NsaConfig;
use hyperoffload::serving::{EngineConfig, ModelCost, SimServingEngine, WorkloadConfig};
use hyperoffload::sim::HwConfig;
use hyperoffload::util::table::{f, pct, Table};

fn main() {
    let model = ModelCost::dsv3_nsa_like();
    let mut hw = HwConfig::ascend910c_like();
    hw.device_capacity = 64_000_000_000;

    // The paper's "unfavourable block-size setting": coarse selection /
    // sliding blocks inflate host-side block processing.
    let coarse = NsaConfig::default().coarse(4);

    let wl = WorkloadConfig::short_sequence(24, 3).generate();
    let base = SimServingEngine::new(EngineConfig::baseline(hw.clone(), model.clone()))
        .run(wl.clone())
        .unwrap();
    let hier = SimServingEngine::new(EngineConfig {
        nsa: coarse,
        ..EngineConfig::hierarchical(hw.clone(), model.clone())
    })
    .run(wl)
    .unwrap();

    let mut t = Table::new(
        "Table 5 — short-sequence latency breakdown (coarse sparse blocks)",
        &["stage", "baseline", "hierarchical", "change", "paper"],
    );
    t.row(&[
        "prefill latency (s, mean)".into(),
        f(base.prefill_latency_us.mean / 1e6, 3),
        f(hier.prefill_latency_us.mean / 1e6, 3),
        pct(hier.prefill_latency_us.mean, base.prefill_latency_us.mean),
        "-0.48%".into(),
    ]);
    t.row(&[
        "decode latency (s/token)".into(),
        f(base.decode_per_token_us.mean / 1e6, 4),
        f(hier.decode_per_token_us.mean / 1e6, 4),
        pct(hier.decode_per_token_us.mean, base.decode_per_token_us.mean),
        "-25.47% (0.117 -> 0.146)".into(),
    ]);
    t.row(&[
        "end-to-end latency (s, mean)".into(),
        f(base.e2e_latency_us.mean / 1e6, 3),
        f(hier.e2e_latency_us.mean / 1e6, 3),
        pct(hier.e2e_latency_us.mean, base.e2e_latency_us.mean),
        "0.15%".into(),
    ]);
    t.print();
    println!(
        "\nnote: the paper reports the slowdown as negative change; decode overhead\n\
         comes from CPU-side partial KV updates on coarse blocks, e2e stays ~flat\n\
         because prefill dominates short-sequence requests."
    );
}
