//! Cluster-wide prefix cache: copy-on-write KV block sharing through the
//! shared pool, measured against the identical trace with its prefix
//! hashes stripped (no sharing, every prompt prefills cold).
//!
//! N = 8 hierarchical replicas share one pool and one prefix index. The
//! workload is a shared-system-prompt trace: 75% of the requests open
//! with one of four 2048-token templates, hashed per 64-token KV block.
//! The sharing row admits the resident blocks from the pool (refcounted,
//! copy-on-write on divergence) and prefills only the un-shared suffix;
//! the stripped row recomputes every template on every request.
//!
//! The run asserts the acceptance criteria: prefill compute saved and
//! pool bytes deduplicated are both > 0, throughput and P99 end-to-end
//! latency strictly beat the no-sharing baseline, and steady-state decode
//! still amortises step compilation (cache hit rate >= 90%).
//!
//! Besides the table the run emits `BENCH_prefix_cache.json` for CI
//! (schema-checked against the committed snapshot at
//! `benches/snapshots/BENCH_prefix_cache.json`). Pass `tiny` as the first
//! argument for the CI-sized workload.

use hyperoffload::serving::{
    ClusterConfig, ClusterReport, EngineConfig, ModelCost, Request, SimCluster,
    WorkloadConfig,
};
use hyperoffload::sim::{HwConfig, GB};
use hyperoffload::util::table::{f, Table};

const REPLICAS: usize = 8;

fn hw() -> HwConfig {
    HwConfig::ascend910c_like().with_device_capacity(64 * GB)
}

/// Prefill-heavy serving point: at 16 GFLOP/token a 64-token block costs
/// ~3.2 ms to recompute but only ~125 us to fetch from the pool, so a
/// prefix hit is a large, schedule-hideable win.
fn model() -> ModelCost {
    ModelCost {
        weights_bytes: 8 * GB,
        act_bytes: GB,
        prefill_flops_per_token: 16e9,
        decode_flops_per_token: 16e9,
        kv_bytes_per_token: 64 * 1024,
    }
}

fn run(wl: Vec<Request>) -> ClusterReport {
    let engine = EngineConfig::hierarchical(hw(), model());
    SimCluster::new(ClusterConfig::new(engine, REPLICAS)).run(wl).expect("cluster run")
}

fn main() {
    let tiny = std::env::args().any(|a| a == "tiny");
    let n_requests = if tiny { 24 } else { 64 };

    // Closed batch (all arrivals at t=0): queueing couples the requests,
    // so saved prefill compute drains the whole cluster earlier.
    let wl = WorkloadConfig::shared_prefix(n_requests, 0.75, 4, 2048, 64, 29).generate();
    let shared_requests = wl.iter().filter(|r| !r.block_hashes.is_empty()).count();
    // The no-sharing baseline is the *same* trace — identical prompt and
    // generation lengths, identical arrivals — with the hashes stripped.
    let stripped: Vec<Request> = wl
        .iter()
        .cloned()
        .map(|mut r| {
            r.block_hashes.clear();
            r
        })
        .collect();

    let rows = [("shared-prefix", run(wl)), ("no-sharing", run(stripped))];

    let mut t = Table::new(
        format!(
            "cluster-wide prefix cache ({REPLICAS} replicas, {n_requests} requests, \
             {shared_requests} sharing 4 templates)"
        ),
        &[
            "config",
            "tok/s",
            "p99 e2e ms",
            "hit blocks",
            "prefill TFLOP saved",
            "pool deduped MB",
            "pool peak GB",
            "cache hit %",
        ],
    );
    for (name, r) in &rows {
        t.row(&[
            (*name).into(),
            f(r.throughput_tok_per_s, 0),
            f(r.e2e_latency_us.p99 / 1e3, 1),
            r.prefix_hit_blocks.to_string(),
            f(r.prefill_flops_saved / 1e12, 2),
            f(r.pool_bytes_deduped as f64 / 1e6, 1),
            f(r.pool_peak_bytes as f64 / 1e9, 2),
            f(r.compile_cache_hit_rate() * 100.0, 1),
        ]);
    }
    t.print();

    let (shared, baseline) = (&rows[0].1, &rows[1].1);
    assert_eq!(shared.completed, n_requests as u64, "sharing run lost requests");
    assert_eq!(baseline.completed, n_requests as u64, "baseline run lost requests");
    assert!(shared.prefix_hit_blocks > 0, "no admission ever hit the prefix cache");
    assert!(shared.prefill_flops_saved > 0.0, "hits must save prefill compute");
    assert!(shared.pool_bytes_deduped > 0, "hits must deduplicate pool bytes");
    assert_eq!(baseline.prefix_hit_blocks, 0, "stripped trace must stay cold");
    assert!(
        shared.throughput_tok_per_s > baseline.throughput_tok_per_s,
        "sharing throughput {} must strictly beat no-sharing {}",
        shared.throughput_tok_per_s,
        baseline.throughput_tok_per_s
    );
    assert!(
        shared.e2e_latency_us.p99 < baseline.e2e_latency_us.p99,
        "sharing p99 {} must strictly beat no-sharing {}",
        shared.e2e_latency_us.p99,
        baseline.e2e_latency_us.p99
    );
    for (name, r) in &rows {
        let rate = r.compile_cache_hit_rate();
        assert!(rate >= 0.9, "{name}: compile-cache hit rate {rate:.3} < 0.90");
    }

    // Machine-readable trajectory for CI (schema-checked, values tracked
    // as an artifact).
    let mut json = String::from("{\n  \"bench\": \"prefix_cache\",\n  \"rows\": [\n");
    for (i, (name, r)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"config\": \"{}\", \"throughput_tok_s\": {:.3}, \
             \"p99_e2e_us\": {:.3}, \"prefix_hit_blocks\": {}, \
             \"prefill_flops_saved\": {:.3e}, \"pool_bytes_deduped\": {}, \
             \"pool_peak_bytes\": {}, \"kv_transfer_bytes\": {}, \
             \"compile_cache_hit_rate\": {:.4}}}{}\n",
            name,
            r.throughput_tok_per_s,
            r.e2e_latency_us.p99,
            r.prefix_hit_blocks,
            r.prefill_flops_saved,
            r.pool_bytes_deduped,
            r.pool_peak_bytes,
            r.kv_transfer_bytes,
            r.compile_cache_hit_rate(),
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ]\n}\n");
    let path = "BENCH_prefix_cache.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }

    println!(
        "\nthe pool stores each shared template once: admissions attach to the\n\
         refcounted blocks (copy-on-write on divergence), prefill runs over\n\
         the un-shared suffix only, and the hit blocks stream pool->device\n\
         under the suffix compute — so the sharing row wins both throughput\n\
         and tail latency on byte-identical downstream work."
    );
}
